"""Discrete-event simulator of the closed batch network (paper Figs. 2, 4-12).

Model: N programs; each program is an endless sequence of tasks. The system
always holds exactly N in-flight tasks; when a task completes, the program's
next task enters immediately and the dispatcher routes it (closed system).

Processing orders (all work-conserving, per Lemma 3):
  * PS   — processor j serves its n_j resident tasks simultaneously; each
           task's remaining "alone time" r = s / mu[i, j] depletes at rate
           1 / n_j wall-seconds per second.
  * FCFS — head-of-line task runs at full rate; the rest wait.
  * PRIO — strict-priority, preemption-free (arXiv:1712.03246): the running
           task always finishes; the next to run is the oldest waiting task
           of the highest-priority class present (class 0 first). With a
           single class this is exactly FCFS.

Priority classes: `SimConfig.class_of_type` maps each task-type row of mu
to a class c in {0..C-1}; both engines then report per-class throughput,
response time, energy and occupancy in `SimMetrics` (single-class configs
get the C == 1 reductions). `class_distributions` gives each class its own
task-size distribution. The priority subsystem (`repro.sched.priority`)
builds these flattened configs from (C, k) per-class mixes.

Energy: a size-s i-type task on processor j occupies the processor for
s / mu[i, j] dedicated seconds in either order, so task energy is
P[i, j] * s / mu[i, j] (paper Sec. 5: execution time, NOT response time).

Two inner loops share the model:

  * Fast path (target policies, `policy.needs_target`): O(l) per event.
    PS runs on per-processor virtual-time clocks (V_j = cumulative
    per-resident service; a task admitted at V_j with need r completes when
    V_j reaches V_j + r), so no per-task depletion pass exists; completion
    queues are per-processor lists sorted descending by (finish, seq) with
    O(1) pop and binary-search insertion (the O(n_j) element shift is a C
    memmove, negligible at closed-network populations). FCFS depletes heads
    only. Occupancy is
    integrated per cell on change (O(1) per event). Task sizes are
    prefetched in blocks (stream-identical to per-event draws) whenever the
    policy path consumes no other randomness. Target policies never read a
    SystemView, so none is built.
  * Compat path (stateless policies, i.e. anything routing on a SystemView):
    the original O(l*N)-per-event loop, kept verbatim because LB's
    backlog_work must be the same pairwise NumPy sum over residents in
    admission order to preserve bit-exact routing parity with the
    pre-refactor goldens.
"""
from __future__ import annotations

import dataclasses
from bisect import insort
from collections import deque

import numpy as np

from repro.core.affinity import PowerModel, PROPORTIONAL_POWER
from repro.sched.api import Policy, SchedulerCore, SystemView, as_core
from repro.sim.distributions import TaskSizeDistribution

_INF = float("inf")
_SIZE_BLOCK = 4096      # prefetch granularity for task-size draws


@dataclasses.dataclass
class SimConfig:
    mu: np.ndarray                      # (k, l) affinity matrix
    n_programs_per_type: np.ndarray     # (k,) programs whose tasks are type i
    distribution: TaskSizeDistribution
    order: str = "PS"                   # "PS" | "FCFS" | "PRIO"
    power: PowerModel = dataclasses.field(default_factory=lambda: PROPORTIONAL_POWER)
    n_completions: int = 20_000
    warmup_completions: int = 2_000
    seed: int = 0
    # If set, each new task's type is re-drawn iid with these probabilities
    # (piecewise-closed operation; dispatchers are notified of mix changes).
    type_mix: np.ndarray | None = None
    # Priority classes: class id (0 = highest priority) of each task-type
    # row; None = every type is class 0. Drives the per-class SimMetrics
    # and the PRIO service order.
    class_of_type: np.ndarray | None = None
    # Per-class task-size distributions (len C); None = `distribution` for
    # every class.
    class_distributions: tuple | None = None
    # Open-network mode (repro.traffic): when set, arrivals inject tasks and
    # completions depart instead of recirculating; n_programs_per_type
    # becomes the reference mix target policies solve at, and finite
    # per-processor queues (traffic.queue_capacity) bound the population.
    # None = the closed network above, bit-identical to pre-traffic runs.
    traffic: "object | None" = None
    # Fault scenario (repro.faults.FaultScenario): crash/recovery and
    # degraded-mu events, transient task failures, checkpoint-restart costs,
    # hedged dispatch (open mode only) and target refresh on topology
    # events. None — or a scenario whose events never fire — leaves every
    # fault-free trajectory bit-identical (dedicated RNG substreams).
    faults: "object | None" = None


@dataclasses.dataclass
class SimMetrics:
    throughput: float                   # X_sim (tasks / sec)
    mean_response_time: float           # E[T_sim]
    mean_energy: float                  # E[E_sim]
    edp: float                          # E[E_sim] * E[T_sim]
    little_product: float               # X_sim * E[T_sim]  (should be ~N)
    completed: int
    elapsed: float
    state_occupancy: np.ndarray         # time-averaged N_ij
    # Occupancy-weighted power draw over the measurement window: the time
    # integral of sum_j W_j (PS: W_j = sum_i N_ij P_ij / c_j; FCFS/PRIO: the
    # running head's P) divided by elapsed. mean_power / throughput is the
    # model's E[E] (eq. 19) measured from the trajectory rather than per
    # completion.
    mean_power: float = 0.0
    # Per-priority-class metrics (C,) / (C, l); the C == 1 reductions for
    # single-class configs. class_throughput sums to `throughput`, and
    # sum_c w_c * class_throughput[c] is the class-weighted X the priority
    # solvers maximize.
    class_throughput: np.ndarray | None = None
    class_response_time: np.ndarray | None = None
    class_energy: np.ndarray | None = None
    class_occupancy: np.ndarray | None = None
    # Open-network (SimConfig.traffic) extras; None on closed runs.
    # offered counts post-warmup arrivals; dropped = shed by admission +
    # rejected by a full finite queue (so goodput = throughput vs the
    # offered rate offered / elapsed). class_quantiles is (C, 3) response
    # p50/p99/p999 (repro.traffic.quantiles.QUANTILES); class_deadline_met
    # is the in-window fraction meeting each class's SLO deadline.
    offered: int | None = None
    dropped: int | None = None
    class_dropped: np.ndarray | None = None
    class_quantiles: np.ndarray | None = None
    class_deadline_met: np.ndarray | None = None
    # Resilience extras (SimConfig.faults); None on fault-free runs.
    # goodput = successful in-window completions / elapsed (== throughput:
    # failed attempts and cancelled hedge partners never count); wasted_work
    # = lost alone-seconds of work (crash rewinds past the last checkpoint,
    # failed attempts, cancelled hedge duplicates) / elapsed; failures
    # counts in-window transient failures; topology_events counts crash
    # breakpoints; reroute_latency averages crash -> next successful
    # completion; recovery_time averages crash -> population back at its
    # pre-crash level (open mode; NaN in closed mode, where the population
    # never moves).
    goodput: float | None = None
    wasted_work: float | None = None
    failures: int | None = None
    topology_events: int | None = None
    reroute_latency: float | None = None
    recovery_time: float | None = None
    # Straggler-triggered speculative backups launched (open mode with
    # faults.hedge_quantile > 0; None elsewhere). Cancelled losers are
    # already charged into wasted_work.
    spec_hedges: int | None = None
    # Observability extras (repro.obs). meta: the run_meta() substrate
    # block (jax backend, kernel mode, dtype) stamped by the engine
    # wrappers so every metrics row says WHERE it was measured. telemetry:
    # time-resolved per-pool series for this row ({occupancy, backlog,
    # power, hedges, bin_width, horizon}) when the run asked for them.
    meta: dict | None = None
    telemetry: dict | None = None


class ClosedNetworkSimulator:
    """Event-driven closed network; O(l) per completion for target policies,
    O(l*N) for SystemView policies."""

    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        self.mu = np.asarray(cfg.mu, dtype=np.float64)
        self.k, self.l = self.mu.shape
        self.P = cfg.power.power_matrix(self.mu)
        if cfg.order not in ("PS", "FCFS", "PRIO"):
            raise ValueError(f"unknown order {cfg.order!r}: PS | FCFS | PRIO")
        self.cls = (np.zeros(self.k, dtype=np.int64)
                    if cfg.class_of_type is None
                    else np.asarray(cfg.class_of_type, dtype=np.int64))
        if self.cls.shape != (self.k,) or self.cls.min() < 0:
            raise ValueError(f"class_of_type must be (k={self.k},) nonneg "
                             f"ints; got {cfg.class_of_type!r}")
        self.n_classes = int(self.cls.max()) + 1
        if (cfg.class_distributions is not None
                and len(cfg.class_distributions) != self.n_classes):
            raise ValueError(f"need {self.n_classes} class_distributions; "
                             f"got {len(cfg.class_distributions)}")
        if cfg.traffic is not None:
            if cfg.traffic.spec.n_classes != self.n_classes:
                raise ValueError(
                    f"traffic spec has {cfg.traffic.spec.n_classes} classes; "
                    f"class_of_type implies {self.n_classes}")
            if cfg.type_mix is not None:
                raise ValueError("type_mix is a closed-network knob; open "
                                 "mode draws types from traffic.spec")
        if cfg.faults is not None:
            if cfg.faults.hedge_classes and cfg.traffic is None:
                raise ValueError("hedge_classes require open/traffic mode "
                                 "(a closed network has no duplicate "
                                 "admission slot)")
            if cfg.faults.hedge_quantile > 0.0 and cfg.traffic is None:
                raise ValueError("hedge_quantile (speculative straggler "
                                 "hedging) requires open/traffic mode")
            if cfg.type_mix is not None and not cfg.faults.is_null:
                raise ValueError("faults + type_mix is not supported in "
                                 "closed mode")

    def run(self, policy: str | Policy | SchedulerCore) -> SimMetrics:
        """Simulate under a policy: a registry name ("cab", "grin", "lb",
        ...), a Policy instance, or a prebuilt SchedulerCore (reset here)."""
        core = as_core(policy, self.mu)
        # Null fault scenarios dispatch to the fault-free loops: trivially
        # bit-identical, and the fault loops stay exercised only when a
        # scenario can actually fire.
        if self.cfg.faults is not None and not self.cfg.faults.is_null:
            if self.cfg.traffic is not None:
                from repro.faults.host import run_open_faults
                return run_open_faults(self, core)
            from repro.faults.host import run_closed_faults
            return run_closed_faults(self, core)
        if self.cfg.traffic is not None:
            from repro.traffic.host import run_open
            return run_open(self, core)
        if core.policy.needs_target:
            return self._run_fast(core)
        return self._run_compat(core)

    # ------------------------------------------------------------------
    # Fast path: target policies — no SystemView, O(l) per event.
    # ------------------------------------------------------------------
    def _run_fast(self, core: SchedulerCore) -> SimMetrics:
        cfg = self.cfg
        k, l = self.k, self.l
        mu_rows = self.mu.tolist()
        P_rows = self.P.tolist()
        rng = np.random.default_rng(cfg.seed)
        n_per_type = np.asarray(cfg.n_programs_per_type, dtype=np.int64)
        n_prog = int(n_per_type.sum())
        order_ps = cfg.order == "PS"
        order_prio = cfg.order == "PRIO"
        cls_l = self.cls.tolist()
        C = self.n_classes
        cdists = cfg.class_distributions

        task_type = np.repeat(np.arange(self.k), n_per_type)
        if cfg.type_mix is not None:
            task_type = rng.choice(self.k, size=n_prog, p=cfg.type_mix)
            mix_counts = np.bincount(task_type, minlength=self.k)
            core.reset(self.mu, mix_counts)
            mix_counts = mix_counts.tolist()    # maintained incrementally
        else:
            core.reset(self.mu, n_per_type)
            mix_counts = None
        task_type = task_type.tolist()

        # Sizes: with the mix fixed, a single distribution and a target
        # policy, the distribution is the only consumer of `rng`, so block
        # draws are stream-identical to per-admission draws (verified for
        # every registry distribution). Per-class distributions interleave
        # draws by class, so they draw per admission like the mix case.
        dist = cfg.distribution
        if mix_counts is None and cdists is None:
            size_buf = dist.sample(rng, _SIZE_BLOCK).tolist()
            size_ptr = 0
        else:
            size_buf = None                     # interleaved draws
            size_ptr = 0

        service_need = [0.0] * n_prog
        entry_time = [0.0] * n_prog
        remaining = [0.0] * n_prog              # FCFS only (heads deplete)
        V = [0.0] * l                           # PS virtual clocks
        n_res = [0] * l
        # PS: per-proc completions sorted ASC by (-finish, -seq): the tail is
        # the earliest finisher with ties broken toward the earliest
        # admission, exactly the original list-order argmin. FCFS: FIFO.
        # PRIO: one FIFO per class per proc + the sticky running head
        # (preemption-free: an arriving high-priority task waits for the
        # running task to finish, then jumps every lower class).
        ps_q: list[list] = [[] for _ in range(l)]
        fifo: list[deque] = [deque() for _ in range(l)]
        prio_q: list[list] = [[deque() for _ in range(C)] for _ in range(l)]
        running = [-1] * l
        seq = 0

        # Per-priority-class accumulators (the totals keep their own scalar
        # accumulators so single-class sums stay bit-identical to pre-PR).
        cls_meas = [0] * C
        cls_resp = [0.0] * C
        cls_energy = [0.0] * C

        # O(1)-per-event occupancy: integrate each (type, proc) cell on
        # change; cnt_rows mirrors core's counts cheaply on the sim side.
        occ_rows = [[0.0] * l for _ in range(k)]
        last_t = [[0.0] * l for _ in range(k)]
        cnt_rows = [[0] * l for _ in range(k)]

        # O(1)-per-event power integration: pw_sum is the instantaneous
        # occupancy-weighted draw sum_j W_j. PS shares each processor, so
        # W_j = sum_{residents} P[type, j] / n_j; FCFS runs the head alone
        # at its full P. Both fold incrementally on admit/complete.
        pw_num = [0.0] * l          # PS: sum of P[type, j] over residents
        head_pw = [0.0] * l         # FCFS: P of the running head (0: idle)
        pw_sum = 0.0
        power_int = 0.0

        route = core.route
        now = 0.0

        def admit(pid: int) -> None:
            nonlocal seq, size_ptr, size_buf, pw_sum
            t = task_type[pid]
            j = route(t)
            if size_buf is None:
                d = dist if cdists is None else cdists[cls_l[t]]
                s = float(d.sample(rng, 1)[0])
            else:
                if size_ptr == _SIZE_BLOCK:
                    size_buf = dist.sample(rng, _SIZE_BLOCK).tolist()
                    size_ptr = 0
                s = size_buf[size_ptr]
                size_ptr += 1
            sn = s / mu_rows[t][j]
            service_need[pid] = sn
            entry_time[pid] = now
            if order_ps:
                old = pw_num[j] / n_res[j] if n_res[j] else 0.0
                pw_num[j] += P_rows[t][j]
                pw_sum += pw_num[j] / (n_res[j] + 1) - old
                insort(ps_q[j], (-(V[j] + sn), -seq, pid))
            elif order_prio:
                if running[j] < 0:          # idle: start immediately
                    running[j] = pid
                    head_pw[j] = P_rows[t][j]
                    pw_sum += head_pw[j]
                else:                       # no preemption: queue by class
                    prio_q[j][cls_l[t]].append(pid)
                remaining[pid] = sn
            else:
                if not fifo[j]:
                    head_pw[j] = P_rows[t][j]
                    pw_sum += head_pw[j]
                remaining[pid] = sn
                fifo[j].append(pid)
            seq += 1
            n_res[j] += 1
            row = cnt_rows[t]
            occ_rows[t][j] += row[j] * (now - last_t[t][j])
            last_t[t][j] = now
            row[j] += 1

        for pid in range(n_prog):
            admit(pid)

        completed = 0
        measured = 0
        t_measure_start = 0.0
        sum_resp = 0.0
        sum_energy = 0.0
        n_completions = cfg.n_completions
        warmup = cfg.warmup_completions
        in_window = warmup <= 0     # == the pre-refactor `completed > warmup`
        occ_started = False         # warmup <= 0 never starts the occ window

        while completed < n_completions:
            # ---- find next completion (O(l)) ----
            best_dt = _INF
            best_j = -1
            if order_ps:
                for j in range(l):
                    q = ps_q[j]
                    if q:
                        dt = (-q[-1][0] - V[j]) * n_res[j]
                        if dt < best_dt:
                            best_dt, best_j = dt, j
            elif order_prio:
                for j in range(l):
                    r = running[j]
                    if r >= 0:
                        dt = remaining[r]
                        if dt < best_dt:
                            best_dt, best_j = dt, j
            else:
                for j in range(l):
                    q = fifo[j]
                    if q:
                        dt = remaining[q[0]]
                        if dt < best_dt:
                            best_dt, best_j = dt, j
            assert best_j >= 0, "no runnable tasks — system cannot be empty"
            power_int += best_dt * pw_sum   # draw over the elapsed interval

            # ---- advance time & deplete (O(l)) ----
            now += best_dt
            j = best_j
            if order_ps:
                for jj in range(l):
                    r = n_res[jj]
                    if r:
                        V[jj] += best_dt / r
                pid = ps_q[j].pop()[2]
            elif order_prio:
                for jj in range(l):
                    r = running[jj]
                    if r >= 0:
                        remaining[r] -= best_dt
                pid = running[j]
            else:
                for jj in range(l):
                    q = fifo[jj]
                    if q:
                        remaining[q[0]] -= best_dt
                pid = fifo[j].popleft()
            n_res[j] -= 1

            # ---- complete ----
            t = task_type[pid]
            if order_ps:
                old = pw_num[j] / (n_res[j] + 1)
                pw_num[j] -= P_rows[t][j]
                pw_sum += (pw_num[j] / n_res[j] if n_res[j] else 0.0) - old
            elif order_prio:
                # next to run: oldest waiting task of the best class present
                pw_sum -= head_pw[j]
                nxt = -1
                for qc in prio_q[j]:
                    if qc:
                        nxt = qc.popleft()
                        break
                running[j] = nxt
                head_pw[j] = P_rows[task_type[nxt]][j] if nxt >= 0 else 0.0
                pw_sum += head_pw[j]
            else:
                pw_sum -= head_pw[j]
                q = fifo[j]
                head_pw[j] = P_rows[task_type[q[0]]][j] if q else 0.0
                pw_sum += head_pw[j]
            core.complete(t, j)
            row = cnt_rows[t]
            occ_rows[t][j] += row[j] * (now - last_t[t][j])
            last_t[t][j] = now
            row[j] -= 1
            completed += 1

            if completed == warmup:     # unreachable when warmup <= 0
                t_measure_start = now
                in_window = True
                occ_started = True
                power_int = 0.0
                for i in range(k):
                    oi, li = occ_rows[i], last_t[i]
                    for jj in range(l):
                        oi[jj] = 0.0
                        li[jj] = now
            elif in_window:
                measured += 1
                resp = now - entry_time[pid]
                energy = P_rows[t][j] * service_need[pid]
                sum_resp += resp
                sum_energy += energy
                c = cls_l[t]
                cls_meas[c] += 1
                cls_resp[c] += resp
                cls_energy[c] += energy

            # ---- the program's next task enters immediately (closed) ----
            if mix_counts is not None:
                tt = int(rng.choice(self.k, p=cfg.type_mix))
                if tt != t:
                    mix_counts[t] -= 1
                    mix_counts[tt] += 1
                    core.notify_type_counts(mix_counts)
                    task_type[pid] = tt
            admit(pid)

        occupancy = np.asarray(occ_rows)
        if occ_started:
            for i in range(k):
                for jj in range(l):
                    occupancy[i, jj] += cnt_rows[i][jj] * (now - last_t[i][jj])
        else:
            occupancy[:] = 0.0      # pre-refactor quirk: warmup==0 tracks none
            power_int = 0.0         # power window follows the occ convention
        return self._metrics(measured, now - t_measure_start, sum_resp,
                             sum_energy, occupancy, power_int,
                             cls_meas, cls_resp, cls_energy)

    # ------------------------------------------------------------------
    # Compat path: SystemView policies (LB/JSQ/RD/BF and custom choosers).
    # Kept op-for-op equal to the pre-refactor loop: LB routes on pairwise
    # NumPy sums of true remaining sizes in admission order, so any change
    # to summation order or tie-breaks would shift its decisions.
    # ------------------------------------------------------------------
    def _run_compat(self, core: SchedulerCore) -> SimMetrics:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed)
        n_per_type = np.asarray(cfg.n_programs_per_type, dtype=np.int64)
        n_prog = int(n_per_type.sum())

        # Per in-flight task state (one task per program).
        task_type = np.repeat(np.arange(self.k), n_per_type)
        if cfg.type_mix is not None:
            task_type = rng.choice(self.k, size=n_prog, p=cfg.type_mix)
        task_proc = np.full(n_prog, -1, dtype=np.int64)
        remaining = np.zeros(n_prog)        # alone-seconds of service left
        size_left = np.zeros(n_prog)        # work units left (for LB view)
        entry_time = np.zeros(n_prog)
        service_need = np.zeros(n_prog)     # total alone-seconds (for energy)

        proc_tasks: list[list[int]] = [[] for _ in range(self.l)]  # FCFS order
        order_prio = cfg.order == "PRIO"
        running = [-1] * self.l             # PRIO: sticky head per processor
        cls_l = self.cls.tolist()
        cdists = cfg.class_distributions
        cls_meas = [0] * self.n_classes
        cls_resp = [0.0] * self.n_classes
        cls_energy = [0.0] * self.n_classes

        mix0 = (n_per_type if cfg.type_mix is None
                else np.bincount(task_type, minlength=self.k))
        core.reset(self.mu, mix0)
        mix_counts = mix0.tolist()          # maintained incrementally
        counts = np.zeros((self.k, self.l), dtype=np.int64)  # sim-side mirror

        def view() -> SystemView:
            backlog_work = np.zeros(self.l)
            backlog_tasks = np.zeros(self.l)
            for j in range(self.l):
                ids = proc_tasks[j]
                backlog_tasks[j] = len(ids)
                if ids:
                    backlog_work[j] = size_left[np.asarray(ids)].sum()
            return SystemView(counts=counts, backlog_work=backlog_work,
                              backlog_tasks=backlog_tasks, mu=self.mu)

        def admit(pid: int, now: float) -> None:
            t = int(task_type[pid])
            j = core.route(t, view=view(), rng=rng)
            counts[t, j] += 1
            d = cfg.distribution if cdists is None else cdists[cls_l[t]]
            s = float(d.sample(rng, 1)[0])
            task_proc[pid] = j
            service_need[pid] = s / self.mu[t, j]
            remaining[pid] = service_need[pid]
            size_left[pid] = s
            entry_time[pid] = now
            proc_tasks[j].append(pid)
            if order_prio and running[j] < 0:
                running[j] = pid

        for pid in range(n_prog):
            admit(pid, 0.0)

        now = 0.0
        completed = 0
        measured = 0
        t_measure_start = 0.0
        sum_resp = 0.0
        sum_energy = 0.0
        occupancy = np.zeros((self.k, self.l))
        occ_t0 = None
        power_int = 0.0

        while completed < cfg.n_completions:
            # ---- find next completion ----
            best_dt, best_j = _INF, -1
            for j in range(self.l):
                ids = proc_tasks[j]
                if not ids:
                    continue
                if cfg.order == "PS":
                    arr = remaining[np.asarray(ids)]
                    dt = arr.min() * len(ids)
                elif order_prio:    # sticky head runs alone, no preemption
                    dt = remaining[running[j]]
                else:  # FCFS: head of line runs alone
                    dt = remaining[ids[0]]
                if dt < best_dt:
                    best_dt, best_j = dt, j
            assert best_j >= 0, "no runnable tasks — system cannot be empty"

            # ---- advance time & deplete ----
            if occ_t0 is not None:
                occupancy += counts * best_dt
                # occupancy-weighted draw (pure reads: routing/rng untouched)
                draw = 0.0
                for jj in range(self.l):
                    ids = proc_tasks[jj]
                    if not ids:
                        continue
                    if cfg.order == "PS":
                        draw += sum(self.P[task_type[i], jj]
                                    for i in ids) / len(ids)
                    elif order_prio:
                        draw += self.P[task_type[running[jj]], jj]
                    else:
                        draw += self.P[task_type[ids[0]], jj]
                power_int += best_dt * draw
            now += best_dt
            j = best_j
            for jj in range(self.l):
                ids = proc_tasks[jj]
                if not ids:
                    continue
                idx = np.asarray(ids)
                if cfg.order == "PS":
                    dep = best_dt / len(ids)
                    remaining[idx] -= dep
                    # size depletes proportionally to service received
                    frac = np.zeros(len(idx))
                    nz = service_need[idx] > 0
                    frac[nz] = dep / service_need[idx][nz]
                    size_left[idx] = np.maximum(
                        size_left[idx] - frac * size_left[idx], 0.0)
                else:
                    head = running[jj] if order_prio else ids[0]
                    remaining[head] -= best_dt
                    # head's size depletes linearly
                    if service_need[head] > 0:
                        size_left[head] = max(
                            size_left[head]
                            - best_dt / service_need[head] * size_left[head],
                            0.0)

            # ---- complete the finished task on processor j ----
            if cfg.order == "PS":
                ids = np.asarray(proc_tasks[j])
                pid = int(ids[np.argmin(remaining[ids])])
            elif order_prio:
                pid = running[j]
            else:
                pid = proc_tasks[j][0]
            t = int(task_type[pid])
            proc_tasks[j].remove(pid)
            if order_prio:
                # next head: oldest (admission order) of the best class
                # present — min() returns the first minimum
                ids = proc_tasks[j]
                running[j] = (min(ids, key=lambda q: cls_l[task_type[q]])
                              if ids else -1)
            core.complete(t, j)
            counts[t, j] -= 1
            completed += 1

            in_window = completed > cfg.warmup_completions
            if completed == cfg.warmup_completions:
                t_measure_start = now
                occ_t0 = now
                occupancy[:] = 0.0
                power_int = 0.0
            if in_window:
                measured += 1
                resp = now - entry_time[pid]
                energy = self.P[t, j] * service_need[pid]
                sum_resp += resp
                sum_energy += energy
                c = cls_l[t]
                cls_meas[c] += 1
                cls_resp[c] += resp
                cls_energy[c] += energy

            # ---- the program's next task enters immediately (closed) ----
            if cfg.type_mix is not None:
                tt = int(rng.choice(self.k, p=cfg.type_mix))
                if tt != t:
                    mix_counts[t] -= 1
                    mix_counts[tt] += 1
                    core.notify_type_counts(mix_counts)
                    task_type[pid] = tt
            admit(pid, now)

        return self._metrics(measured, now - t_measure_start, sum_resp,
                             sum_energy, occupancy, power_int,
                             cls_meas, cls_resp, cls_energy)

    def _metrics(self, measured: int, elapsed: float, sum_resp: float,
                 sum_energy: float, occupancy: np.ndarray,
                 power_int: float = 0.0, cls_meas=None, cls_resp=None,
                 cls_energy=None) -> SimMetrics:
        x = measured / elapsed if elapsed > 0 else 0.0
        et = sum_resp / measured if measured else _INF
        ee = sum_energy / measured if measured else _INF
        occ = occupancy / max(elapsed, 1e-12)
        C = self.n_classes
        cm = np.asarray(cls_meas if cls_meas is not None else [measured],
                        dtype=np.float64)
        cr = np.asarray(cls_resp if cls_resp is not None else [sum_resp])
        ce = np.asarray(cls_energy if cls_energy is not None else [sum_energy])
        with np.errstate(divide="ignore", invalid="ignore"):
            cls_x = cm / elapsed if elapsed > 0 else np.zeros(C)
            cls_rt = np.where(cm > 0, cr / np.maximum(cm, 1.0), _INF)
            cls_ee = np.where(cm > 0, ce / np.maximum(cm, 1.0), _INF)
        cls_occ = np.zeros((C, occupancy.shape[1]))
        np.add.at(cls_occ, self.cls, occ)
        return SimMetrics(throughput=x, mean_response_time=et, mean_energy=ee,
                          edp=ee * et, little_product=x * et,
                          completed=measured, elapsed=elapsed,
                          state_occupancy=occ,
                          mean_power=power_int / elapsed if elapsed > 0
                          else 0.0,
                          class_throughput=cls_x, class_response_time=cls_rt,
                          class_energy=cls_ee, class_occupancy=cls_occ)


def run_policy_sweep(cfg: SimConfig, policies,
                     engine: str = "host") -> dict[str, SimMetrics]:
    """Run the same workload under each policy; results keyed by display name.

    `policies` is an iterable of registry names, Policy instances, or
    SchedulerCores. `engine` selects the simulator:

      * "host" (default) — the event-driven host core; one NumPy stream per
        run (same seed => same task sizes), bit-reproducible across versions.
      * "jax"  — target policies run on the batched `lax.scan` device engine
        (its own JAX random stream: statistically equivalent, not
        bit-identical to host runs), including piecewise type-mix workloads
        (on-device re-draw, target pinned at the expected mix); SystemView
        policies fall back to the host core.
      * "auto" — alias for "jax" with its fallbacks.
    """
    if engine not in ("host", "jax", "auto"):
        raise ValueError(f"unknown engine {engine!r}: host | jax | auto")
    sim = ClosedNetworkSimulator(cfg)
    # the device engine needs a real measurement window; degenerate warmups
    # (legal on the host: zero measured completions) fall back too
    jax_ok = (engine in ("jax", "auto")
              and 0 <= cfg.warmup_completions < cfg.n_completions)
    out: dict[str, SimMetrics] = {}
    for c in (as_core(p, cfg.mu) for p in policies):
        key, n = c.name, 2
        while key in out:                       # e.g. two 'Opt' variants
            key = f"{c.name}#{n}"
            n += 1
        if jax_ok and c.policy.needs_target:
            from repro.sim.engine_jax import simulate_policy_jax
            out[key] = simulate_policy_jax(cfg, c)
        else:
            out[key] = sim.run(c)
    return out
