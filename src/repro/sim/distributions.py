"""Task-size distributions (paper Sec. 5), all normalized to mean 1.

Sizes are in work units; a size-s i-type task needs s / mu[i, j] seconds of
dedicated service on processor j.
"""
from __future__ import annotations

import dataclasses

import numpy as np


class TaskSizeDistribution:
    name = "base"

    def sample(self, rng: np.random.Generator, n: int = 1) -> np.ndarray:
        raise NotImplementedError

    @property
    def mean(self) -> float:
        return 1.0


@dataclasses.dataclass
class Exponential(TaskSizeDistribution):
    """Markovian case classical queueing theory assumes."""

    name: str = "exponential"

    def sample(self, rng, n=1):
        return rng.exponential(1.0, size=n)


@dataclasses.dataclass
class Uniform(TaskSizeDistribution):
    """U[0, 2] (mean 1)."""

    name: str = "uniform"

    def sample(self, rng, n=1):
        return rng.uniform(0.0, 2.0, size=n)


@dataclasses.dataclass
class Constant(TaskSizeDistribution):
    name: str = "constant"

    def sample(self, rng, n=1):
        return np.ones(n)


@dataclasses.dataclass
class BoundedPareto(TaskSizeDistribution):
    """Heavy-tailed bounded Pareto on [low, high], normalized to mean 1.

    pdf(x) ~ alpha * low^alpha * x^(-alpha-1) / (1 - (low/high)^alpha).
    Sampled by inverse CDF, then divided by the analytic mean so E[size] = 1
    (the paper's distributions are mean-matched across Figs. 4-7).
    """

    alpha: float = 1.5
    low: float = 1.0
    high: float = 1000.0
    name: str = "bounded_pareto"

    def __post_init__(self):
        a, L, H = self.alpha, self.low, self.high
        if a == 1.0:
            raw_mean = L * np.log(H / L) / (1.0 - L / H)
        else:
            raw_mean = (a * L**a / (1.0 - (L / H)**a)
                        * (L**(1.0 - a) - H**(1.0 - a)) / (a - 1.0))
        object.__setattr__(self, "_raw_mean", float(raw_mean))

    def sample(self, rng, n=1):
        a, L, H = self.alpha, self.low, self.high
        u = rng.uniform(0.0, 1.0, size=n)
        # Inverse CDF of bounded Pareto.
        x = (-(u * H**a - u * L**a - H**a) / (H**a * L**a)) ** (-1.0 / a)
        return x / self._raw_mean


@dataclasses.dataclass
class HyperExponential(TaskSizeDistribution):
    """Hyperexponential mixture (heavy-tailed, high CV), normalized to
    mean 1: with probability probs[i] the size is Exp(rates[i]). The
    defaults (90% fast / 10% slow at 25x the mean) give CV^2 ~ 10 — the
    classic two-phase model for bursty request sizes, and the tail shape
    the log-histogram quantile accumulator is validated on.
    """

    probs: tuple = (0.9, 0.1)
    rates: tuple = (2.0, 0.08)
    name: str = "hyperexp"

    def __post_init__(self):
        p = np.asarray(self.probs, dtype=np.float64)
        r = np.asarray(self.rates, dtype=np.float64)
        if p.shape != r.shape or p.ndim != 1 or p.size < 1:
            raise ValueError("probs and rates must be matching 1-D tuples")
        if (p < 0).any() or not np.isclose(p.sum(), 1.0) or (r <= 0).any():
            raise ValueError("probs must be a probability vector and "
                             "rates positive")
        object.__setattr__(self, "_raw_mean", float((p / r).sum()))

    def sample(self, rng, n=1):
        comp = rng.choice(len(self.probs), size=n, p=self.probs)
        x = rng.exponential(1.0, size=n) / np.asarray(self.rates)[comp]
        return x / self._raw_mean


@dataclasses.dataclass
class Weibull(TaskSizeDistribution):
    """Weibull with shape ``k``, normalized to mean 1.

    ``k < 1`` is heavy-tailed with decreasing hazard (many tiny tasks,
    rare huge ones), ``k > 1`` concentrates around the mean with
    increasing hazard, and ``k = 1`` degenerates to Exponential. The
    same family parameterizes the up/down availability processes in
    `repro.faults.hazard`; here it is a task-size law. numpy's
    ``rng.weibull(k)`` draws scale-1 variates with mean Gamma(1 + 1/k),
    so we divide by that to mean-match.
    """

    k: float = 2.0
    name: str = "weibull"

    def __post_init__(self):
        if not self.k > 0:
            raise ValueError(f"weibull shape must be > 0, got {self.k}")
        from math import gamma
        object.__setattr__(self, "_raw_mean", float(gamma(1.0 + 1.0 / self.k)))

    def sample(self, rng, n=1):
        return rng.weibull(self.k, size=n) / self._raw_mean


DISTRIBUTIONS = {
    "exponential": Exponential,
    "bounded_pareto": BoundedPareto,
    "uniform": Uniform,
    "constant": Constant,
    "hyperexp": HyperExponential,
    "weibull": Weibull,
}


def make_distribution(name: str, **kw) -> TaskSizeDistribution:
    return DISTRIBUTIONS[name](**kw)
