"""Attention implementations (pure JAX).

Three tiers exist in this repo:
  * `naive_attention`            — oracle, O(S^2) memory, tiny tests only.
  * `chunked_attention` (here)   — online-softmax over KV chunks, bounded
                                   memory; the default model path on CPU and
                                   the dry-run lowering path. Mathematically
                                   identical to flash attention.
  * `repro.kernels.flash_attention` — Pallas TPU kernel (runtime path on TPU).

All support GQA (H grouped over KV heads, no materialized head repeat),
causality, and optional sliding windows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_reshape(q, n_kv):
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def naive_attention(q, k, v, *, causal=True, window=0, q_offset=0):
    """Oracle. q: (B,Sq,H,dh); k,v: (B,Sk,KV,dh). Returns (B,Sq,H,dh)."""
    b, sq, h, dh = q.shape
    kv = k.shape[2]
    qg = _gqa_reshape(q, kv).astype(jnp.float32)
    scores = jnp.einsum("bsngd,btnd->bngst", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(dh).astype(jnp.float32)
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((sq, k.shape[1]), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngst,btnd->bsngd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, dh).astype(q.dtype)


def chunked_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                      chunk_q=1024, chunk_k=1024):
    """Online-softmax attention, O(chunk_q * chunk_k) score memory.

    Outer scan over query chunks, inner scan over KV chunks with running
    (max, sum, acc) carry — the flash-attention recurrence in plain jnp.
    """
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    kv = k.shape[2]
    g = h // kv
    cq = min(chunk_q, sq)
    ck = min(chunk_k, sk)
    # pad to multiples
    pq = (-sq) % cq
    pk = (-sk) % ck
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = qp.shape[1] // cq, kp.shape[1] // ck

    # leading axis = chunk index (scan axis)
    qb = qp.reshape(b, nq, cq, kv, g, dh).transpose(1, 0, 2, 3, 4, 5).astype(jnp.float32)
    kb = kp.reshape(b, nk, ck, kv, dh).transpose(1, 0, 2, 3, 4).astype(jnp.float32)
    vb = vp.reshape(b, nk, ck, kv, dh).transpose(1, 0, 2, 3, 4).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)

    qpos_all = jnp.arange(nq * cq).reshape(nq, cq) + q_offset
    kpos_all = jnp.arange(nk * ck).reshape(nk, ck)
    k_valid = (kpos_all < sk)

    def one_q_chunk(carry, xq):
        qc, qpos = xq                              # (b,cq,kv,g,dh), (cq,)
        m0 = jnp.full((b, cq, kv, g), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, cq, kv, g), jnp.float32)
        a0 = jnp.zeros((b, cq, kv, g, dh), jnp.float32)

        def step(state, xk):
            m, l, acc = state
            kc, vc, kpos, kval = xk
            s = jnp.einsum("bcngd,btnd->bcngt", qc, kc) * scale  # (b,cq,kv,g,ck)
            mask = jnp.broadcast_to(kval[None, :], (cq, ck))
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            if window:
                mask = mask & (kpos[None, :] > qpos[:, None] - window)
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum("bcngt,btnd->bcngd", p, vc)
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                      (kb, vb, kpos_all, k_valid))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return carry, out

    _, outs = jax.lax.scan(one_q_chunk, None, (qb, qpos_all))
    # outs: (nq, b, cq, kv, g, dh) -> (b, sq, h, dh)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * cq, h, dh)
    return out[:, :sq].astype(q.dtype)


def decode_attention(q, k_cache, v_cache, pos, *, window=0, kpos=None):
    """Single-step attention against a cache.

    q: (B, 1, H, dh); caches: (B, S, KV, dh); pos: scalar current position
    (number of tokens already cached). `kpos` optionally supplies the absolute
    position of every cache slot (ring buffers); defaults to arange(S).
    """
    b, _, h, dh = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    qg = q.reshape(b, kv, h // kv, dh).astype(jnp.float32)
    scores = jnp.einsum("bngd,btnd->bngt", qg, k_cache.astype(jnp.float32))
    scores = scores / jnp.sqrt(dh).astype(jnp.float32)
    if kpos is None:
        kpos = jnp.arange(s)
    valid = (kpos >= 0) & (kpos <= pos)
    if window:
        valid &= kpos > pos - window
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngt,btnd->bngd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, dh).astype(q.dtype)
