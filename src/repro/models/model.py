"""Unified model API over all 10 assigned architectures.

`build_model(cfg)` returns a `Model` whose methods are pure functions:

    init(key) -> params
    forward(params, batch) -> logits (fp32)
    loss(params, batch) -> (scalar, metrics)
    init_cache(batch_size, cache_len) -> cache pytree
    prefill(params, batch) -> (last_logits, cache)
    decode_step(params, tokens, cache, pos) -> (logits, cache)
    input_specs(shape) -> batch of ShapeDtypeStructs (dry-run stand-ins)

Layer stacks are lax.scan-ed over stacked params ("stack_*" subtrees) so the
HLO stays compact for 512-device compiles; remat applies per scanned block.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import layers as L
from repro.parallel.sharding import constrain


def _split(key, n):
    return list(jax.random.split(key, n))


# --------------------------------------------------------------- block defs

def _init_dense_block(key, cfg: ModelConfig, moe: bool) -> dict:
    ks = _split(key, 2)
    d = cfg.d_model
    p = {"ln1": jnp.zeros((d,), jnp.dtype(cfg.param_dtype)),
         "ln2": jnp.zeros((d,), jnp.dtype(cfg.param_dtype)),
         "attn": L.init_attention(ks[0], cfg)}
    if moe:
        p["moe"] = L.init_moe(ks[1], cfg)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg)
    return p


def _apply_dense_block(p, x, cfg, *, positions, mode, cache, want_cache,
                       window=0):
    a, c = L.apply_attention(p["attn"], L.rmsnorm(x, p["ln1"], cfg.norm_eps),
                             cfg, positions=positions, mode=mode,
                             cache=None if cache is None else cache["attn"],
                             want_cache=want_cache, window=window)
    x = x + a
    aux = jnp.asarray(0.0, jnp.float32)
    h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        m, aux = L.apply_moe(p["moe"], h, cfg)
    else:
        m = L.apply_mlp(p["mlp"], h, cfg)
    x = x + m
    new_cache = None if c is None else {"attn": c}
    return x, new_cache, aux


def _init_mamba_block(key, cfg) -> dict:
    return {"ln": jnp.zeros((cfg.d_model,), jnp.dtype(cfg.param_dtype)),
            "mamba": L.init_mamba(key, cfg)}


def _apply_mamba_block(p, x, cfg, *, mode, cache, want_cache):
    h = L.rmsnorm(x, p["ln"], cfg.norm_eps)
    y, c = L.apply_mamba(p["mamba"], h, cfg, mode=mode,
                         cache=None if cache is None else cache["mamba"],
                         want_cache=want_cache)
    return x + y, (None if c is None else {"mamba": c})


def _init_mlstm_block(key, cfg) -> dict:
    return {"ln": jnp.zeros((cfg.d_model,), jnp.dtype(cfg.param_dtype)),
            "mlstm": L.init_mlstm(key, cfg)}


def _apply_mlstm_block(p, x, cfg, *, mode, cache, want_cache):
    h = L.rmsnorm(x, p["ln"], cfg.norm_eps)
    y, c = L.apply_mlstm(p["mlstm"], h, cfg, mode=mode,
                         cache=None if cache is None else cache["mlstm"],
                         want_cache=want_cache)
    return x + y, (None if c is None else {"mlstm": c})


def _init_slstm_block(key, cfg) -> dict:
    return {"ln": jnp.zeros((cfg.d_model,), jnp.dtype(cfg.param_dtype)),
            "slstm": L.init_slstm(key, cfg)}


def _apply_slstm_block(p, x, cfg, *, mode, cache, want_cache):
    h = L.rmsnorm(x, p["ln"], cfg.norm_eps)
    y, c = L.apply_slstm(p["slstm"], h, cfg, mode=mode,
                         cache=None if cache is None else cache["slstm"],
                         want_cache=want_cache)
    return x + y, (None if c is None else {"slstm": c})


# --------------------------------------------------------------- model

@dataclasses.dataclass
class Model:
    cfg: ModelConfig

    # ---------------- init ----------------
    def init(self, key) -> dict:
        cfg = self.cfg
        pdt = jnp.dtype(cfg.param_dtype)
        ks = _split(key, 8)
        params: dict[str, Any] = {"ln_f": jnp.zeros((cfg.d_model,), pdt)}

        if cfg.family == "audio":
            params["embed"] = (jax.random.normal(
                ks[0], (cfg.n_codebooks, cfg.vocab_size, cfg.d_model)) * 0.02
            ).astype(pdt)
            params["heads"] = (jax.random.normal(
                ks[1], (cfg.n_codebooks, cfg.d_model, cfg.vocab_size)) * 0.02
            ).astype(pdt)
        else:
            params["embed"] = (jax.random.normal(
                ks[0], (cfg.vocab_size, cfg.d_model)) * 0.02).astype(pdt)
            if not cfg.tie_embeddings:
                params["lm_head"] = (jax.random.normal(
                    ks[1], (cfg.d_model, cfg.vocab_size)) * 0.02).astype(pdt)
        if cfg.family == "vlm":
            params["patch_proj"] = L.dense_init(ks[2], (cfg.d_model, cfg.d_model), cfg)

        lkey = ks[3]
        if cfg.family in ("dense", "audio", "vlm"):
            params["stack"] = jax.vmap(
                lambda k: _init_dense_block(k, cfg, moe=False)
            )(jnp.stack(_split(lkey, cfg.n_layers)))
        elif cfg.family == "moe":
            params["stack"] = jax.vmap(
                lambda k: _init_dense_block(k, cfg, moe=True)
            )(jnp.stack(_split(lkey, cfg.n_layers)))
        elif cfg.family == "hybrid":
            g, r = divmod(cfg.n_layers, cfg.attn_every)
            gk = jnp.stack(_split(lkey, g * cfg.attn_every)).reshape(
                g, cfg.attn_every, 2)
            params["stack_groups"] = jax.vmap(jax.vmap(
                lambda k: _init_mamba_block(k, cfg)))(gk)
            params["shared"] = _init_dense_block(ks[4], cfg, moe=False)
            if r:
                params["stack_tail"] = jax.vmap(
                    lambda k: _init_mamba_block(k, cfg)
                )(jnp.stack(_split(ks[5], r)))
        elif cfg.family == "ssm":  # xLSTM
            g = cfg.n_layers // cfg.slstm_every
            m = cfg.slstm_every - 1
            mk = jnp.stack(_split(lkey, g * m)).reshape(g, m, 2)
            params["stack_groups"] = {
                "mlstm": jax.vmap(jax.vmap(
                    lambda k: _init_mlstm_block(k, cfg)))(mk),
                "slstm": jax.vmap(lambda k: _init_slstm_block(k, cfg))(
                    jnp.stack(_split(ks[6], g))),
            }
        else:
            raise ValueError(f"unknown family {cfg.family}")
        return params

    # ---------------- embedding / head ----------------
    def _embed(self, params, batch):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        if cfg.family == "audio":
            tok = batch["tokens"]                     # (B, K, S)
            embs = [jnp.take(params["embed"][k], tok[:, k], axis=0)
                    for k in range(cfg.n_codebooks)]
            x = sum(embs).astype(dt)
        else:
            x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dt)
        if cfg.family == "vlm" and "patch_embeds" in batch:
            pe = batch["patch_embeds"].astype(dt) @ params["patch_proj"].astype(dt)
            x = jnp.concatenate([pe, x], axis=1)
        return constrain(x, "dp", None, None)

    def _head(self, params, x):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
        if cfg.family == "audio":
            logits = jnp.einsum("bsd,kdv->bskv", x, params["heads"].astype(dt))
        elif cfg.tie_embeddings:
            logits = x @ params["embed"].T.astype(dt)
        else:
            logits = x @ params["lm_head"].astype(dt)
        return constrain(logits.astype(jnp.float32), "dp", None, "tp") \
            if cfg.family != "audio" else logits.astype(jnp.float32)

    # ---------------- stack application ----------------
    def _run_stack(self, params, x, *, positions, mode, caches, want_cache):
        """Returns (x, new_caches, aux_sum)."""
        cfg = self.cfg
        remat = cfg.remat and mode == "full" and not want_cache

        def maybe_remat(fn):
            return jax.checkpoint(fn) if remat else fn

        aux_total = jnp.asarray(0.0, jnp.float32)

        if cfg.family in ("dense", "moe", "audio", "vlm"):
            def body(carry, xs):
                h, aux = carry
                p, c = xs
                h, nc, a = _apply_dense_block(p, h, cfg, positions=positions,
                                              mode=mode, cache=c,
                                              want_cache=want_cache)
                return (h, aux + a), nc

            (x, aux_total), new = jax.lax.scan(
                maybe_remat(body), (x, aux_total),
                (params["stack"], caches["stack"] if caches else None))
            return x, ({"stack": new} if (want_cache or mode == "decode") else None), aux_total

        if cfg.family == "hybrid":
            win = cfg.sliding_window

            def group(carry, xs):
                h, aux = carry
                p, c = xs

                def inner(hh, mxs):
                    mp, mc = mxs
                    hh, nmc = _apply_mamba_block(mp, hh, cfg, mode=mode,
                                                 cache=mc, want_cache=want_cache)
                    return hh, nmc

                h, new_m = jax.lax.scan(
                    inner, h, (p["mamba_stack"], c["mamba"] if c else None))
                h, new_a, a = _apply_dense_block(
                    params["shared"], h, cfg, positions=positions, mode=mode,
                    cache=c["attn"] if c else None, want_cache=want_cache,
                    window=win)
                return (h, aux + a), {"mamba": new_m, "attn": new_a}

            gc = caches["groups"] if caches else None
            (x, aux_total), new_g = jax.lax.scan(
                maybe_remat(group), (x, aux_total),
                ({"mamba_stack": params["stack_groups"]}, gc))
            new_t = None
            if "stack_tail" in params:
                def tail(carry, xs):
                    h, aux = carry
                    p, c = xs
                    h, nc = _apply_mamba_block(p, h, cfg, mode=mode, cache=c,
                                               want_cache=want_cache)
                    return (h, aux), nc
                (x, aux_total), new_t = jax.lax.scan(
                    maybe_remat(tail), (x, aux_total),
                    (params["stack_tail"], caches["tail"] if caches else None))
            out_c = None
            if want_cache or mode == "decode":
                out_c = {"groups": new_g}
                if new_t is not None:
                    out_c["tail"] = new_t
            return x, out_c, aux_total

        if cfg.family == "ssm":
            def group(carry, xs):
                h, aux = carry
                p, c = xs

                def inner(hh, mxs):
                    mp, mc = mxs
                    hh, nmc = _apply_mlstm_block(mp, hh, cfg, mode=mode,
                                                 cache=mc, want_cache=want_cache)
                    return hh, nmc

                h, new_m = jax.lax.scan(
                    inner, h, (p["mlstm"], c["mlstm"] if c else None))
                h, new_s = _apply_slstm_block(p["slstm"], h, cfg, mode=mode,
                                              cache=c["slstm"] if c else None,
                                              want_cache=want_cache)
                return (h, aux), {"mlstm": new_m, "slstm": new_s}

            gc = caches["groups"] if caches else None
            (x, aux_total), new_g = jax.lax.scan(
                maybe_remat(group), (x, aux_total),
                (params["stack_groups"], gc))
            out_c = {"groups": new_g} if (want_cache or mode == "decode") else None
            return x, out_c, aux_total

        raise ValueError(cfg.family)

    # ---------------- public API ----------------
    def forward(self, params, batch, *, want_cache=False):
        cfg = self.cfg
        x = self._embed(params, batch)
        s = x.shape[1]
        positions = jnp.arange(s)
        x, caches, aux = self._run_stack(params, x, positions=positions,
                                         mode="full", caches=None,
                                         want_cache=want_cache)
        logits = self._head(params, x)
        if want_cache:
            return logits, caches, aux
        return logits, aux

    def loss(self, params, batch):
        cfg = self.cfg
        if cfg.loss_chunk and cfg.family not in ("audio",):
            return self._loss_chunked(params, batch)
        logits, aux = self.forward(params, batch)
        targets = batch["targets"]
        if cfg.family == "audio":
            # logits (B,S,K,V), targets (B,K,S)
            tt = targets.transpose(0, 2, 1)                      # (B,S,K)
            lp = jax.nn.log_softmax(logits, axis=-1)
            nll = -jnp.take_along_axis(lp, tt[..., None], axis=-1)[..., 0]
            mask = jnp.ones(tt.shape, jnp.float32)
        else:
            if cfg.family == "vlm":
                npad = logits.shape[1] - targets.shape[1]
                logits = logits[:, npad:]
            lp = jax.nn.log_softmax(logits, axis=-1)
            nll = -jnp.take_along_axis(lp, targets[..., None], axis=-1)[..., 0]
            mask = batch.get("loss_mask",
                             jnp.ones(targets.shape, jnp.float32))
        loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
        total = loss + aux
        return total, {"ce": loss, "aux": aux}

    def _loss_chunked(self, params, batch):
        """CE via a scan over sequence chunks: fp32 logits are materialized
        only (B, chunk, V) at a time — at 152k vocab this is the difference
        between 2.5 GB and 300 MB of logits per device (§Perf iter 7)."""
        cfg = self.cfg
        x = self._embed(params, batch)
        positions = jnp.arange(x.shape[1])
        x, _, aux = self._run_stack(params, x, positions=positions,
                                    mode="full", caches=None, want_cache=False)
        targets = batch["targets"]
        if cfg.family == "vlm":
            x = x[:, x.shape[1] - targets.shape[1]:]
        mask = batch.get("loss_mask", jnp.ones(targets.shape, jnp.float32))
        b, s, d = x.shape
        c = min(cfg.loss_chunk, s)
        pad = (-s) % c
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
            targets = jnp.pad(targets, ((0, 0), (0, pad)))
            mask = jnp.pad(mask, ((0, 0), (0, pad)))
        n = x.shape[1] // c
        xs = (x.reshape(b, n, c, d).swapaxes(0, 1),
              targets.reshape(b, n, c).swapaxes(0, 1),
              mask.reshape(b, n, c).swapaxes(0, 1))

        def chunk(carry, inp):
            nll_sum, m_sum = carry
            xc, tc, mc = inp
            logits = self._head(params, xc)
            lp = jax.nn.log_softmax(logits, axis=-1)
            nll = -jnp.take_along_axis(lp, tc[..., None], axis=-1)[..., 0]
            return (nll_sum + (nll * mc).sum(), m_sum + mc.sum()), None

        (nll_sum, m_sum), _ = jax.lax.scan(chunk, (0.0, 0.0), xs)
        loss = nll_sum / jnp.maximum(m_sum, 1.0)
        return loss + aux, {"ce": loss, "aux": aux}

    # ---------------- caches / serving ----------------
    def init_cache(self, batch: int, cache_len: int):
        cfg = self.cfg

        def stackify(tree, *ns):
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a, tuple(ns) + a.shape), tree)

        if cfg.family in ("dense", "moe", "audio", "vlm"):
            base = {"attn": L.attention_cache_spec(cfg, batch, cache_len, 0)}
            return {"stack": stackify(base, cfg.n_layers)}
        if cfg.family == "hybrid":
            g, r = divmod(cfg.n_layers, cfg.attn_every)
            mam = {"mamba": L.mamba_cache_spec(cfg, batch)}
            att = {"attn": L.attention_cache_spec(cfg, batch, cache_len,
                                                  cfg.sliding_window)}
            out = {"groups": {"mamba": stackify(mam, g, cfg.attn_every),
                              "attn": stackify(att, g)}}
            if r:
                out["tail"] = stackify(mam, r)
            return out
        if cfg.family == "ssm":
            g = cfg.n_layers // cfg.slstm_every
            m = cfg.slstm_every - 1
            return {"groups": {
                "mlstm": stackify({"mlstm": L.mlstm_cache_spec(cfg, batch)}, g, m),
                "slstm": stackify({"slstm": L.slstm_cache_spec(cfg, batch)}, g),
            }}
        raise ValueError(cfg.family)

    def prefill(self, params, batch, cache_len: int | None = None):
        """Full-sequence pass building the cache; the head is applied ONLY to
        the final position (materializing (B, S, V) logits at 32k would be
        hundreds of GB). `cache_len` pads attention caches with empty slots
        (kpos = -1) so subsequent decode steps have room to append."""
        x = self._embed(params, batch)
        positions = jnp.arange(x.shape[1])
        x, caches, _ = self._run_stack(params, x, positions=positions,
                                       mode="full", caches=None,
                                       want_cache=True)
        logits = self._head(params, x[:, -1:])
        if cache_len is not None:
            caches = _pad_attention_caches(caches, cache_len,
                                           self.cfg.sliding_window)
        return logits, caches

    def decode_step(self, params, tokens, cache, pos):
        """tokens: (B, 1) (audio: (B, K, 1)); pos: scalar int32 = number of
        tokens already processed. Returns (logits_for_new_token, new_cache)."""
        cfg = self.cfg
        x = self._embed(params, {"tokens": tokens})
        x, new_cache, _ = self._run_stack(params, x, positions=pos,
                                          mode="decode", caches=cache,
                                          want_cache=False)
        logits = self._head(params, x)
        return logits, new_cache

    # ---------------- dry-run stand-ins ----------------
    def input_specs(self, shape: ShapeConfig) -> dict:
        """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        sds = jax.ShapeDtypeStruct
        if shape.kind == "train":
            if cfg.family == "audio":
                out = {"tokens": sds((B, cfg.n_codebooks, S), i32),
                       "targets": sds((B, cfg.n_codebooks, S), i32)}
            else:
                out = {"tokens": sds((B, S), i32), "targets": sds((B, S), i32)}
            if cfg.family == "vlm":
                out["patch_embeds"] = sds((B, cfg.n_patches, cfg.d_model),
                                          jnp.dtype(cfg.dtype))
            return out
        if shape.kind == "prefill":
            if cfg.family == "audio":
                out = {"tokens": sds((B, cfg.n_codebooks, S), i32)}
            else:
                out = {"tokens": sds((B, S), i32)}
            if cfg.family == "vlm":
                out["patch_embeds"] = sds((B, cfg.n_patches, cfg.d_model),
                                          jnp.dtype(cfg.dtype))
            return out
        if shape.kind == "decode":
            if cfg.family == "audio":
                return {"tokens": sds((B, cfg.n_codebooks, 1), i32)}
            return {"tokens": sds((B, 1), i32)}
        raise ValueError(shape.kind)


def _pad_attention_caches(caches, cache_len: int, window: int):
    """Pad every attention cache's sequence axis to its target ring size:
    min(window, cache_len) for windowed attention, else cache_len. Empty
    slots carry kpos = -1 (masked out by decode_attention)."""
    target = min(window, cache_len) if window else cache_len

    def pad(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        if name in ("k", "v"):
            cur = leaf.shape[-3]
            if cur < target:
                pads = [(0, 0)] * leaf.ndim
                pads[-3] = (0, target - cur)
                return jnp.pad(leaf, pads)
        elif name == "kpos":
            cur = leaf.shape[-1]
            if cur < target:
                pads = [(0, 0)] * leaf.ndim
                pads[-1] = (0, target - cur)
                return jnp.pad(leaf, pads, constant_values=-1)
        return leaf

    return jax.tree_util.tree_map_with_path(pad, caches)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)


def count_params(cfg: ModelConfig) -> int:
    import math as _math
    m = build_model(cfg)
    tree = jax.eval_shape(lambda: m.init(jax.random.PRNGKey(0)))
    return int(sum(_math.prod(l.shape) for l in jax.tree.leaves(tree)))
