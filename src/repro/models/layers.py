"""Layer library: init + apply for every block kind used by the 10 archs.

Conventions:
  * params are plain nested dicts of jnp arrays (param_dtype, default fp32);
    compute casts to cfg.dtype (default bf16) at use.
  * every `apply_*` works in two modes:
      mode="full"   — whole sequence (train / prefill); returns fresh cache
                      pieces when `want_cache`.
      mode="decode" — one token against a cache; returns updated cache.
  * sharding is annotated via logical axes (repro.parallel.sharding.constrain)
    and is a no-op outside a mesh context.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models.attention import decode_attention
from repro.models.linear_scan import linear_scan_step
from repro.parallel.sharding import constrain


# ---------------------------------------------------------------- utilities

def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def dense_init(key, shape, cfg, scale=None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(_pdtype(cfg))


def rmsnorm(x, w, eps):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def rope(x, positions, theta):
    """x: (..., S, H, dh) or (..., H, dh) with matching positions (..., S) /
    scalar. Standard half-split rotation."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (math.log(theta) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs       # (..., S?, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                                       # broadcast heads
    sin = sin[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * cos - xf2 * sin,
                            xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------- attention

def init_attention(key, cfg: ModelConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 3)
    p = {
        "wqkv": dense_init(ks[0], (d, (h + 2 * kv) * hd), cfg),
        "wo": dense_init(ks[1], (h * hd, d), cfg, scale=1.0 / math.sqrt(h * hd)),
    }
    if cfg.qkv_bias:
        p["bqkv"] = jnp.zeros(((h + 2 * kv) * hd,), _pdtype(cfg))
    return p


def apply_attention(p, x, cfg: ModelConfig, *, positions, mode="full",
                    cache=None, want_cache=False, window=0):
    """x: (B, S, D). positions: (S,) absolute (full) or scalar pos (decode).

    cache (decode or prefill-output): {"k","v": (B, Sc, KV, hd),
    "kpos": (Sc,) int32, "idx": scalar write cursor}.
    """
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    dt = _dtype(cfg)
    qkv = x @ p["wqkv"].astype(dt)
    if "bqkv" in p:
        qkv = qkv + p["bqkv"].astype(dt)
    q, k, v = jnp.split(qkv, [h * hd, (h + kv) * hd], axis=-1)
    q = constrain(q.reshape(b, s, h, hd), "dp", None, "tp", None)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)

    if mode == "decode":
        pos = positions  # scalar: number of tokens already in cache
        q = rope(q, jnp.asarray(pos)[None], cfg.rope_theta)
        k = rope(k, jnp.asarray(pos)[None], cfg.rope_theta)
        # Write into the slot holding the oldest (or empty, kpos=-1) position;
        # correctness only depends on kpos, not slot order, so this covers
        # both append-style full caches and sliding-window ring buffers.
        widx = jnp.argmin(cache["kpos"]).astype(jnp.int32)
        kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, widx, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, widx, 0, 0))
        kpos = jax.lax.dynamic_update_slice(cache["kpos"],
                                            jnp.asarray(pos)[None].astype(jnp.int32),
                                            (widx,))
        out = decode_attention(q, kc, vc, pos, window=window, kpos=kpos)
        new_cache = {"k": kc, "v": vc, "kpos": kpos, "idx": cache["idx"] + 1}
    else:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        out = ops.flash_attention(q, k, v, causal=True, window=window,
                                  block_q=cfg.attn_chunk_q,
                                  block_k=cfg.attn_chunk_k)
        new_cache = None
        if want_cache:
            sc = min(window, s) if window else s
            new_cache = {
                "k": constrain(k[:, -sc:].astype(dt), "dp", "sp", None, None),
                "v": constrain(v[:, -sc:].astype(dt), "dp", "sp", None, None),
                "kpos": positions[-sc:].astype(jnp.int32),
                "idx": jnp.asarray(s, jnp.int32),
            }
    out = constrain(out, "dp", None, "tp", None)
    y = out.reshape(b, s, h * hd) @ p["wo"].astype(dt)
    return constrain(y, "dp", None, None), new_cache


def attention_cache_spec(cfg: ModelConfig, batch: int, seq_len: int, window: int):
    sc = min(window, seq_len) if window else seq_len
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    dt = _dtype(cfg)
    return {
        "k": jnp.zeros((batch, sc, kv, hd), dt),
        "v": jnp.zeros((batch, sc, kv, hd), dt),
        "kpos": jnp.full((sc,), -1, jnp.int32),
        "idx": jnp.asarray(0, jnp.int32),
    }


# ---------------------------------------------------------------- MLP / MoE

def init_mlp(key, cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"wu": dense_init(ks[1], (d, f), cfg),
         "wd": dense_init(ks[2], (f, d), cfg, scale=1.0 / math.sqrt(f))}
    if cfg.mlp_style == "swiglu":
        p["wg"] = dense_init(ks[0], (d, f), cfg)
    return p


def apply_mlp(p, x, cfg: ModelConfig):
    dt = _dtype(cfg)
    u = x @ p["wu"].astype(dt)
    if "wg" in p:                                   # SwiGLU (3 matrices)
        h = jax.nn.silu(x @ p["wg"].astype(dt)) * u
    else:                                           # GeLU (2 matrices)
        h = jax.nn.gelu(u)
    h = constrain(h, "dp", None, "tp")
    return constrain(h @ p["wd"].astype(dt), "dp", None, None)


def init_moe(key, cfg: ModelConfig) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 3)
    return {
        "router": dense_init(ks[0], (d, e), cfg, scale=0.02),
        "w_in": dense_init(ks[1], (e, d, 2 * f), cfg),
        "w_out": dense_init(ks[2], (e, f, d), cfg, scale=1.0 / math.sqrt(f)),
    }


def apply_moe(p, x, cfg: ModelConfig):
    """Top-k routed experts: shard_map-local dispatch + weight-gather FFN.

    Under a mesh, the WHOLE MoE block runs inside shard_map over the dp axes:
    every shard selects its own top-C_local tokens per expert, gathers,
    applies the expert FFN and scatter-adds back — dispatch never crosses
    shards (EXPERIMENTS.md §Perf iter 6: a global-jit dispatch makes XLA
    replicate the top-k/scatter, catastrophically at 256-way dp). Expert
    weights enter replicated (in_specs P()), i.e. one all-gather per layer
    call — the ZeRO-style weight-gather MoE appropriate for 512-wide experts
    (DESIGN.md §5). Returns (y, aux_loss).
    """
    from repro.parallel import sharding as shctx
    mesh = shctx.current_mesh()
    tp = mesh.shape.get("model", 1) if mesh is not None else 1
    if mesh is None or (x.shape[1] == 1 and cfg.n_experts % tp == 0):
        # No mesh (smoke tests), or decode with cleanly TP-sharded experts
        # (a handful of tokens): the global path avoids gathering expert
        # weights per token step (§Perf: moe-1b decode 4.8 GB -> 55 MB).
        # Non-divisible expert counts (E=40, tp=16) keep the shard_map path:
        # their weights are replicated anyway and the global scatter reshards.
        return _apply_moe_local(p, x, cfg)
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    dp = shctx._CTX["rules"].get("dp") or ("data",)

    def local_fn(xl, router, w_in, w_out):
        y, aux = _apply_moe_local(
            {"router": router, "w_in": w_in, "w_out": w_out}, xl, cfg)
        return y, jax.lax.pmean(aux, axis_name=dp)

    y, aux = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(dp, None, None), P(), P(), P()),
        out_specs=(P(dp, None, None), P()), check_rep=False,
    )(x, p["router"], p["w_in"], p["w_out"])
    return y, aux


def _apply_moe_local(p, x, cfg: ModelConfig):
    """Shard-local MoE math (also the no-mesh smoke-test path)."""
    b, s, d = x.shape
    e, k, f = cfg.n_experts, cfg.top_k, cfg.moe_d_ff
    dt = _dtype(cfg)
    t = b * s
    xf = x.reshape(t, d)
    scores = (xf @ p["router"].astype(dt)).astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(scores, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                # (T, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # combine weights (T, E): zero except chosen experts
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)      # (T, K, E)
    comb = (onehot * gate_vals[..., None]).sum(axis=1)           # (T, E)

    # capacity per expert (per shard-local token count under pjit this is the
    # global T; the sharded top_k lowers to a distributed selection).
    # Decode (s == 1) is dropless: a dropped token would corrupt generation.
    if s == 1:
        cap = t
    else:
        cap = max(1, int(math.ceil(t * k / e * cfg.capacity_factor)))
        cap = min(cap, t)
    sel_scores = comb.T                                          # (E, T)
    top_w, top_i = jax.lax.top_k(sel_scores, cap)                # (E, C)
    keep = top_w > 0.0
    xin = jnp.take(xf, top_i.reshape(-1), axis=0).reshape(e, cap, d)
    xin = xin * keep[..., None].astype(dt)   # no constraints: runs in shard_map

    h = jnp.einsum("ecd,edf->ecf", xin, p["w_in"].astype(dt))
    g, u = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(g) * u
    y_e = jnp.einsum("ecf,efd->ecd", h, p["w_out"].astype(dt))   # (E, C, D)
    y_e = y_e * (top_w * keep)[..., None].astype(dt)

    y = jnp.zeros((t, d), dt).at[top_i.reshape(-1)].add(
        y_e.reshape(e * cap, d), mode="drop")
    y = y.reshape(b, s, d)

    # load-balancing aux loss (Switch-style)
    frac_tokens = (onehot.sum(1) > 0).astype(jnp.float32).mean(axis=0)  # (E,)
    frac_probs = probs.mean(axis=0)
    aux = cfg.router_aux_coef * e * jnp.sum(frac_tokens * frac_probs)
    return y, aux


# ---------------------------------------------------------------- Mamba2

def init_mamba(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    din = cfg.d_inner
    ds = cfg.ssm_state
    hs = cfg.n_ssm_heads
    conv_ch = din + 2 * ds
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * din + 2 * ds + hs), cfg),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv_width, conv_ch), cfg, scale=0.5),
        "conv_b": jnp.zeros((conv_ch,), _pdtype(cfg)),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, hs)).astype(_pdtype(cfg)),
        "Dskip": jnp.ones((hs,), _pdtype(cfg)),
        "dt_bias": jnp.full((hs,), -2.0, _pdtype(cfg)),
        "out_proj": dense_init(ks[2], (din, d), cfg, scale=1.0 / math.sqrt(din)),
        "norm_g": jnp.zeros((din,), _pdtype(cfg)),
    }


def _causal_conv_full(u, w, b):
    """u: (B, S, C); depthwise causal conv width W. Returns (B, S, C)."""
    W = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + u.shape[1]] * w[i][None, None, :] for i in range(W))
    return out + b[None, None, :]


def apply_mamba(p, x, cfg: ModelConfig, *, mode="full", cache=None,
                want_cache=False):
    """Mamba2 (SSD) block. cache: {"state": (B,Hs,ds,hd) f32,
    "conv": (B, W-1, conv_ch)}."""
    b, s, d = x.shape
    din, ds, hs, hd = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    W = cfg.ssm_conv_width
    dt = _dtype(cfg)
    proj = x @ p["in_proj"].astype(dt)
    z, xs, Bc, Cc, dts = jnp.split(
        proj, [din, 2 * din, 2 * din + ds, 2 * din + 2 * ds], axis=-1)
    conv_in = jnp.concatenate([xs, Bc, Cc], axis=-1)             # (B,S,conv_ch)

    if mode == "decode":
        hist = jnp.concatenate([cache["conv"].astype(dt), conv_in], axis=1)
        conv_out = (sum(hist[:, i:i + 1] * p["conv_w"].astype(dt)[i][None, None]
                        for i in range(W)) + p["conv_b"].astype(dt)[None, None])
        new_conv = hist[:, 1:]
    else:
        conv_out = _causal_conv_full(conv_in, p["conv_w"].astype(dt),
                                     p["conv_b"].astype(dt))
        new_conv = None
        if want_cache:
            padded = jnp.pad(conv_in, ((0, 0), (max(W - 1 - s, 0), 0), (0, 0)))
            new_conv = padded[:, -(W - 1):]
    conv_out = jax.nn.silu(conv_out)
    xs, Bc, Cc = jnp.split(conv_out, [din, din + ds], axis=-1)

    xh = xs.reshape(b, s, hs, hd)                                # v
    Bh = jnp.repeat(Bc[:, :, None, :], hs, axis=2)               # k: (B,S,Hs,ds)
    Ch = jnp.repeat(Cc[:, :, None, :], hs, axis=2)               # q
    dtv = jax.nn.softplus(dts.astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))    # (B,S,Hs)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                 # (Hs,) < 0
    log_a = dtv * A[None, None, :]                               # <= 0

    if mode == "decode":
        y, state = linear_scan_step(Ch[:, 0], Bh[:, 0], xh[:, 0], log_a[:, 0],
                                    dtv[:, 0], cache["state"])
        y = y[:, None]                                           # (B,1,Hs,hd)
        new_cache = {"state": state, "conv": new_conv}
    else:
        y, state = ops.ssd_scan(Ch, Bh, xh, log_a, dtv, chunk=cfg.ssm_chunk)
        new_cache = ({"state": state, "conv": new_conv} if want_cache else None)

    y = y + p["Dskip"].astype(dt)[None, None, :, None] * xh
    y = y.reshape(b, s, din)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_g"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(dt)
    return constrain(out, "dp", None, None), new_cache


def mamba_cache_spec(cfg: ModelConfig, batch: int):
    return {
        "state": jnp.zeros((batch, cfg.n_ssm_heads, cfg.ssm_state,
                            cfg.ssm_head_dim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1,
                           cfg.d_inner + 2 * cfg.ssm_state), _dtype(cfg)),
    }


# ---------------------------------------------------------------- xLSTM

def init_mlstm(key, cfg: ModelConfig) -> dict:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wqkv": dense_init(ks[0], (d, 3 * h * hd), cfg),
        "wif": dense_init(ks[1], (d, 2 * h), cfg, scale=0.02),
        "w_ogate": dense_init(ks[2], (d, h * hd), cfg, scale=0.02),
        "wo": dense_init(ks[3], (h * hd, d), cfg, scale=1.0 / math.sqrt(h * hd)),
        "ln_inner": jnp.zeros((h, hd), _pdtype(cfg)),
    }


def apply_mlstm(p, x, cfg: ModelConfig, *, mode="full", cache=None,
                want_cache=False):
    """mLSTM: matrix-memory linear attention with sigmoid forget / input
    gates. cache: {"C": (B,H,hd,hd) f32, "n": (B,H,hd,1) f32}."""
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    dt = _dtype(cfg)
    qkv = x @ p["wqkv"].astype(dt)
    q, k, v = (t.reshape(b, s, h, hd) for t in jnp.split(qkv, 3, axis=-1))
    q = q / math.sqrt(hd)
    gates = (x @ p["wif"].astype(dt)).astype(jnp.float32)
    ig, fg = jnp.split(gates, 2, axis=-1)                        # (B,S,H)
    log_f = jax.nn.log_sigmoid(fg)
    i_in = jax.nn.sigmoid(ig)
    ones = jnp.ones((b, s, h, 1), dt)

    if mode == "decode":
        y, C = linear_scan_step(q[:, 0], k[:, 0], v[:, 0], log_f[:, 0],
                                i_in[:, 0], cache["C"])
        _, n = linear_scan_step(q[:, 0], k[:, 0], ones[:, 0], log_f[:, 0],
                                i_in[:, 0], cache["n"])
        nm = jnp.einsum("bhk,bhkv->bhv", q[:, 0].astype(jnp.float32), n)
        y = (y / jnp.maximum(jnp.abs(nm), 1.0)).astype(dt)[:, None]
        new_cache = {"C": C, "n": n}
    else:
        y, C = ops.ssd_scan(q, k, v, log_f, i_in, chunk=cfg.ssm_chunk)
        nm_seq, n = ops.ssd_scan(q, k, ones, log_f, i_in, chunk=cfg.ssm_chunk)
        y = (y / jnp.maximum(jnp.abs(nm_seq.astype(jnp.float32)), 1.0)).astype(dt)
        new_cache = ({"C": C, "n": n} if want_cache else None)

    y = rmsnorm(y, p["ln_inner"], cfg.norm_eps)
    og = jax.nn.sigmoid(x @ p["w_ogate"].astype(dt)).reshape(b, s, h, hd)
    y = (y * og).reshape(b, s, h * hd)
    return constrain(y @ p["wo"].astype(dt), "dp", None, None), new_cache


def mlstm_cache_spec(cfg: ModelConfig, batch: int):
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    return {"C": jnp.zeros((batch, h, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, h, hd, 1), jnp.float32)}


def init_slstm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    return {"w_gates": dense_init(key, (d, 4 * d), cfg, scale=0.02)}


def apply_slstm(p, x, cfg: ModelConfig, *, mode="full", cache=None,
                want_cache=False):
    """sLSTM with per-channel scalar memory. Recurrent hidden-to-gate weights
    are omitted (R=0) to admit a parallel associative scan on TPU —
    documented adaptation (DESIGN.md §4). cache: {"c","n": (B, D) f32}."""
    b, s, d = x.shape
    dt = _dtype(cfg)
    pre = (x @ p["w_gates"].astype(dt)).astype(jnp.float32)
    ig, fg, zg, og = jnp.split(pre, 4, axis=-1)                  # (B,S,D)
    i = jnp.exp(jnp.clip(ig, -8.0, 8.0))
    f = jax.nn.sigmoid(fg)
    z = jnp.tanh(zg)
    o = jax.nn.sigmoid(og)

    if mode == "decode":
        c = f[:, 0] * cache["c"] + i[:, 0] * z[:, 0]
        n = f[:, 0] * cache["n"] + i[:, 0]
        hcur = (o[:, 0] * c / jnp.maximum(n, 1.0))[:, None]
        new_cache = {"c": c, "n": n}
        return hcur.astype(dt), new_cache

    def op(a, b_):
        (fa, xa), (fb, xb) = a, b_
        return fa * fb, xb + fb * xa

    f_c, c = jax.lax.associative_scan(op, (f, i * z), axis=1)
    f_n, n = jax.lax.associative_scan(op, (f, i), axis=1)
    hseq = o * c / jnp.maximum(n, 1.0)
    new_cache = ({"c": c[:, -1], "n": n[:, -1]} if want_cache else None)
    return hseq.astype(dt), new_cache


def slstm_cache_spec(cfg: ModelConfig, batch: int):
    return {"c": jnp.zeros((batch, cfg.d_model), jnp.float32),
            "n": jnp.zeros((batch, cfg.d_model), jnp.float32)}
