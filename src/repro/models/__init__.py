"""Model zoo: transformer/SSM/hybrid families used as real workloads."""
