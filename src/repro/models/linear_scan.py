"""Generic chunked linear-recurrence ("state space dual") primitive.

Per head, with state S in R^{dk x dv}:

    S_t = a_t * S_{t-1} + beta_t * k_t v_t^T          (a_t in (0, 1])
    y_t = q_t @ S_t                                    -> R^{dv}

Mamba2 maps (k=B_t, v=x_t, q=C_t, a=exp(dt*A), beta=dt); mLSTM maps
(k, v, q, a=f_gate, beta=i_gate) and reuses the same primitive with dv=1 for
its normalizer. Three tiers:

  * `linear_scan_ref`     — sequential lax.scan oracle.
  * `linear_scan_chunked` — chunked parallel form (intra-chunk attention-like
                            + inter-chunk state scan); the model/dry-run path.
  * `repro.kernels.ssd_scan` — Pallas TPU kernel of the same chunked form.

Numerical stability: all decay products live in log space; every exp argument
is a difference of cumulative logs ordered so that it is <= 0.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def linear_scan_ref(q, k, v, log_a, beta, s0=None):
    """Sequential oracle.

    q, k: (B, S, H, dk); v: (B, S, H, dv); log_a, beta: (B, S, H).
    Returns y: (B, S, H, dv), final state (B, H, dk, dv).
    """
    b, s, h, dk = k.shape
    dv = v.shape[-1]
    f32 = jnp.float32
    if s0 is None:
        s0 = jnp.zeros((b, h, dk, dv), f32)

    def step(S, x):
        qt, kt, vt, lat, bt = x
        S = (jnp.exp(lat)[..., None, None] * S
             + bt[..., None, None] * kt[..., :, None] * vt[..., None, :])
        y = jnp.einsum("bhk,bhkv->bhv", qt, S)
        return S, y

    xs = (q.transpose(1, 0, 2, 3).astype(f32), k.transpose(1, 0, 2, 3).astype(f32),
          v.transpose(1, 0, 2, 3).astype(f32), log_a.transpose(1, 0, 2).astype(f32),
          beta.transpose(1, 0, 2).astype(f32))
    S, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3).astype(v.dtype), S


def linear_scan_step(q, k, v, log_a, beta, state):
    """One decode step. q,k: (B,H,dk); v: (B,H,dv); log_a,beta: (B,H);
    state: (B,H,dk,dv). Returns (y (B,H,dv), new_state)."""
    f32 = jnp.float32
    S = (jnp.exp(log_a.astype(f32))[..., None, None] * state
         + beta.astype(f32)[..., None, None]
         * k.astype(f32)[..., :, None] * v.astype(f32)[..., None, :])
    y = jnp.einsum("bhk,bhkv->bhv", q.astype(f32), S)
    return y.astype(v.dtype), S


def linear_scan_chunked(q, k, v, log_a, beta, s0=None, chunk=256):
    """Chunked parallel form; exact same math as the sequential oracle."""
    b, s, h, dk = k.shape
    dv = v.shape[-1]
    f32 = jnp.float32
    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        zf = lambda x: jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2))
        q, k, v, beta = zf(q), zf(k), zf(v), zf(beta)
        log_a = jnp.pad(log_a, [(0, 0), (0, pad), (0, 0)])  # a=1 on pad: log 0
    n = q.shape[1] // c

    def to_chunks(x):
        # (B, S, H, ...) -> (n, B, c, H, ...) with chunk index leading (scan)
        return x.reshape((b, n, c) + x.shape[2:]).swapaxes(0, 1).astype(f32)

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    lac, bc = to_chunks(log_a), to_chunks(beta)

    la_cum = jnp.cumsum(lac, axis=2)                  # (n, B, c, H) inclusive
    la_tot = la_cum[:, :, -1]                          # (n, B, H)

    if s0 is None:
        s0 = jnp.zeros((b, h, dk, dv), f32)

    def chunk_step(S, xs):
        qi, ki, vi, lci, lti, bi = xs
        # intra-chunk: D[t, u] = exp(lc[t] - lc[u]) for u <= t else 0.
        # Mask BEFORE exp: above-diagonal diffs are positive and can overflow
        # to inf, which would poison gradients via 0 * inf = NaN.
        diff = lci[:, :, None, :] - lci[:, None, :, :]          # (B, c, c, H)
        tri = jnp.tril(jnp.ones((c, c), bool))
        dmat = jnp.exp(jnp.where(tri[None, :, :, None], diff, -1e30))
        scores = jnp.einsum("bthk,buhk->btuh", qi, ki) * dmat    # (B,c,c,H)
        y_intra = jnp.einsum("btuh,buh,buhv->bthv", scores, bi, vi)
        # inter-chunk: y_t += exp(lc[t]) * q_t @ S_prev
        y_inter = jnp.exp(lci)[..., None] * jnp.einsum("bthk,bhkv->bthv", qi, S)
        # state update: S = exp(lt) * S + sum_u exp(lt - lc[u]) * b_u k_u v_u^T
        w = jnp.exp(lti[:, None, :] - lci) * bi                  # (B, c, H)
        S_new = (jnp.exp(lti)[..., None, None] * S
                 + jnp.einsum("buh,buhk,buhv->bhkv", w, ki, vi))
        return S_new, y_intra + y_inter

    S, ys = jax.lax.scan(chunk_step, s0, (qc, kc, vc, la_cum, la_tot, bc))
    y = ys.swapaxes(0, 1).reshape(b, n * c, h, dv)
    # Padded tail has beta=0 and log_a=0, so the final state S is unaffected.
    return y[:, :s].astype(v.dtype), S
