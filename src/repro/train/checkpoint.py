"""Checkpointing: atomic, keep-last-k, optional async; no orbax dependency.

Layout:  <dir>/step_<n>/arrays.npz + tree.json  (+ .tmp staging, atomic
rename). `save` flattens any pytree with jax.tree_util key paths; `restore`
rebuilds the exact structure. Works with sharded arrays (gathers to host —
adequate for the CPU container; on a real pod each process would write its
own shard file, same layout with a process suffix).
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import numpy as np

import jax


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(directory: str, step: int, tree, keep: int = 3,
         async_: bool = False) -> threading.Thread | None:
    """Write checkpoint for `step`. Returns the writer thread if async."""

    def _write():
        os.makedirs(directory, exist_ok=True)
        final = os.path.join(directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(tree)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        treedef = jax.tree_util.tree_structure(tree)
        with open(os.path.join(tmp, "tree.json"), "w") as f:
            json.dump({"step": step, "treedef": str(treedef),
                       "keys": sorted(flat)}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                     # atomic publish
        _gc(directory, keep)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _gc(directory: str, keep: int):
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d))


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(directory: str, template, step: int | None = None):
    """Restore into the structure of `template` (shapes must match)."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_t = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for kp, leaf in flat_t[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in kp)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(flat_t[1], leaves), step
