"""Fault tolerance: checkpoint/restart driver, elastic re-meshing, straggler
mitigation hooks.

The three mechanisms a 1000-node deployment needs, and how they appear here:

1. **Checkpoint/restart** — `run_with_recovery` wraps the step loop: any
   exception triggers restore-from-latest and replay (the data pipeline is
   step-indexed, so replay is exact). Checkpoint cadence + async writes keep
   the overhead off the step path.

2. **Elastic scaling** — `ElasticMeshManager` rebuilds the mesh and re-shards
   live state when the healthy-device set changes; on a real fleet this is
   driven by jax.distributed heartbeats, here by an injectable device-list
   provider (tests inject failures). Re-sharding = device_put to the new
   NamedSharding (same PartitionSpecs — specs are mesh-shape-agnostic).

3. **Straggler mitigation** — per-pool observed step-rates feed an EWMA into
   the paper's scheduler (repro.sched): a slow pool's mu column drops, GrIn
   re-solves, and load migrates away — the queueing-theoretic version of
   backup tasks. `StragglerTracker` is that EWMA.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding

from repro.train import checkpoint as ckpt

log = logging.getLogger("repro.ft")


def run_with_recovery(step_fn: Callable, state, data_iter,
                      *, ckpt_dir: str, ckpt_every: int = 100,
                      max_steps: int = 1000, max_restarts: int = 3,
                      async_ckpt: bool = True):
    """Run step_fn(state, batch) with checkpoint/restore-based recovery.

    Returns (state, steps_completed, restarts). step indices come from the
    data iterator so replay-after-restore is exact.
    """
    restarts = 0
    pending = None
    step = int(np.asarray(state.step)) if hasattr(state, "step") else 0
    while step < max_steps:
        try:
            for i, batch in data_iter:
                if i >= max_steps:
                    break
                state, metrics = step_fn(state, batch)
                step = i + 1
                if step % ckpt_every == 0:
                    if pending is not None:
                        pending.join()
                    pending = ckpt.save(ckpt_dir, step, state,
                                        async_=async_ckpt)
            break
        except Exception as e:  # noqa: BLE001 — any fault triggers recovery
            restarts += 1
            log.warning("step %d failed (%s); restart %d", step, e, restarts)
            # Drain any in-flight async checkpoint BEFORE touching ckpt_dir:
            # restoring (or re-raising) while the writer thread is mid-file
            # would race latest_step/restore against a half-written step.
            if pending is not None:
                pending.join()
                pending = None
            if restarts > max_restarts:
                raise
            latest = ckpt.latest_step(ckpt_dir)
            if latest is not None:
                state, step = ckpt.restore(ckpt_dir, state)
            data_iter.seek(step) if hasattr(data_iter, "seek") else None
    if pending is not None:
        pending.join()
    return state, step, restarts


@dataclasses.dataclass
class ElasticMeshManager:
    """Rebuild mesh + re-shard state when the device set changes."""

    axis_names: tuple
    device_provider: Callable = jax.devices   # injectable for failure tests

    def current_mesh(self) -> Mesh:
        devs = self.device_provider()
        n = len(devs)
        # factor n into (data, model): keep model as square as possible
        model = 1
        for m in (16, 8, 4, 2, 1):
            if n % m == 0:
                model = m
                break
        shape = (n // model, model)
        return jax.make_mesh(shape, self.axis_names[-2:])

    def reshard(self, tree, spec_tree, mesh: Mesh):
        return jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
            tree, spec_tree)


class StragglerTracker:
    """EWMA of per-pool speed RELATIVE to expectation (1.0 = nominal).

    Observations must be normalized per task class (expected/actual service
    time) — raw rates would conflate a pool's task mix with its health."""

    def __init__(self, n_pools: int, alpha: float = 0.3):
        self.alpha = alpha
        self.rates = np.ones(n_pools)     # relative speed, 1.0 = nominal
        self.seen = np.zeros(n_pools, dtype=bool)

    def observe(self, pool: int, rel_speed: float):
        """rel_speed = expected_service_s / actual_service_s."""
        if not self.seen[pool]:
            self.rates[pool] = rel_speed
            self.seen[pool] = True
        else:
            self.rates[pool] = (self.alpha * rel_speed
                                + (1 - self.alpha) * self.rates[pool])

    def slowdown_factors(self) -> np.ndarray:
        """Per-pool relative speed (<1 = straggler, >1 = faster than nominal).

        Normalized so the fleet-best healthy pool anchors at its own scale —
        the scheduler multiplies base mu columns by these factors."""
        return np.where(self.seen, self.rates, 1.0)
