"""Training stack: data, optimizer, train step, checkpoint, fault tolerance."""
