"""Synthetic-corpus data pipeline: deterministic, resumable, sharded.

The "corpus" is a seeded Zipfian token stream with document structure (EOS
every ~doc_len tokens) — enough statistical texture for training dynamics
tests without shipping a dataset. Determinism: batch `i` depends only on
(seed, i), so resuming from step k after a failure replays identically
(fault-tolerance substrate), and each data shard draws a disjoint slice.

A background thread prefetches `prefetch` batches ahead of the consumer.
"""
from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np

import jax


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    doc_len: int = 512
    zipf_a: float = 1.2
    n_codebooks: int = 0        # audio: (B, K, S) token grids
    n_patches: int = 0          # vlm: synthetic patch embeddings
    d_model: int = 0


def _batch_at(cfg: DataConfig, index: int) -> dict:
    """Batch `index`, deterministically (resume == replay)."""
    rng = np.random.default_rng((cfg.seed, index))
    shape = ((cfg.global_batch, cfg.n_codebooks, cfg.seq_len + 1)
             if cfg.n_codebooks else (cfg.global_batch, cfg.seq_len + 1))
    # Zipf with rejection to vocab (heavy-tailed like real token streams).
    toks = rng.zipf(cfg.zipf_a, size=shape) % (cfg.vocab_size - 2) + 2
    # document boundaries
    eos_mask = rng.random(shape) < (1.0 / cfg.doc_len)
    toks = np.where(eos_mask, 1, toks).astype(np.int32)
    batch = {"tokens": toks[..., :-1], "targets": toks[..., 1:]}
    if cfg.n_patches:
        batch["patch_embeds"] = rng.standard_normal(
            (cfg.global_batch, cfg.n_patches, cfg.d_model)).astype(np.float32)
    return batch


class DataPipeline:
    """Iterator with background prefetch and step-indexed resume."""

    def __init__(self, cfg: DataConfig, start_step: int = 0, prefetch: int = 2,
                 shard_fn=None):
        self.cfg = cfg
        self._shard_fn = shard_fn or (lambda x: x)
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._next = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        i = self._next
        while not self._stop.is_set():
            batch = _batch_at(self.cfg, i)
            try:
                self._q.put((i, batch), timeout=0.5)
                i += 1
            except queue.Full:
                continue

    def __iter__(self):
        return self

    def __next__(self):
        i, batch = self._q.get()
        return i, {k: self._shard_fn(v) for k, v in batch.items()}

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)


def batch_for_step(cfg: DataConfig, step: int) -> dict:
    """Direct access (tests / single steps)."""
    return _batch_at(cfg, step)
