"""AdamW + schedule + clipping + optional int8 gradient compression.

No optax dependency — the optimizer is ~80 lines and keeping it explicit makes
the sharding story obvious: optimizer state mirrors the parameter tree, so the
same PartitionSpecs apply leaf-for-leaf (ZeRO: both are FSDP-sharded).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    min_lr_ratio: float = 0.1
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # int8 gradient compression with error feedback (applied to the gradient
    # representation before the data-parallel reduction term; OFF by default).
    compress_grads: bool = False


def lr_at(cfg: OptimizerConfig, step):
    """Linear warmup + cosine decay to min_lr_ratio * lr."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(math.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_opt_state(params, cfg: OptimizerConfig) -> dict:
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), p)
    state = {"m": zeros(params), "v": zeros(params),
             "step": jnp.zeros((), jnp.int32)}
    if cfg.compress_grads:
        state["err"] = zeros(params)   # error-feedback residual
    return state


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def quantize_int8(x):
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def apply_updates(params, grads, state, cfg: OptimizerConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

    if cfg.compress_grads:
        # error-feedback int8: transmit q(g + err); keep residual locally.
        def comp(g, e):
            q, s = quantize_int8(g + e)
            deq = dequantize_int8(q, s)
            return deq, (g + e) - deq
        pairs = jax.tree.map(comp, gf, state["err"])
        gf = jax.tree.map(lambda p: p[0], pairs,
                          is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda p: p[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))
    gnorm = _global_norm(gf)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip > 0 else 1.0
    gf = jax.tree.map(lambda g: g * scale, gf)

    lr = lr_at(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0  # no decay on norms
        newp = p.astype(jnp.float32) - lr * (delta + decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, gf, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    if cfg.compress_grads:
        new_state["err"] = new_err
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
