"""Train step factory: microbatched gradient accumulation + AdamW.

`make_train_step(model, opt_cfg, microbatches)` returns a pure
`train_step(state, batch) -> (state, metrics)` suitable for jit/pjit. The
global batch is split into `microbatches` slices scanned sequentially
(gradient accumulation) — this is what bounds activation memory at
train_4k x 30B scale; each microbatch's forward is remat'd per layer inside
the model's scan.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.parallel.sharding import constrain
from repro.train.optimizer import (OptimizerConfig, apply_updates,
                                   init_opt_state)


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    step: Any

    def tree_flatten(self):
        return (self.params, self.opt, self.step), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    TrainState, TrainState.tree_flatten, TrainState.tree_unflatten)


def init_train_state(model: Model, key, opt_cfg: OptimizerConfig) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=init_opt_state(params, opt_cfg),
                      step=jnp.zeros((), jnp.int32))


def _split_micro(batch: dict, n: int) -> dict:
    """(B, ...) -> (n, B/n, ...), keeping the microbatch shards on 'dp'."""
    def f(x):
        b = x.shape[0]
        assert b % n == 0, f"global batch {b} not divisible by {n} microbatches"
        xm = x.reshape((n, b // n) + x.shape[1:])
        return constrain(xm, None, "dp", *([None] * (x.ndim - 1)))
    return jax.tree.map(f, batch)


def make_train_step(model: Model, opt_cfg: OptimizerConfig,
                    microbatches: int = 1, zero_stage: int = 2):
    """zero_stage=3: fp32 master params are used directly (fully sharded;
    XLA re-gathers per layer per microbatch). zero_stage=2 (default,
    EXPERIMENTS.md §Perf iteration 1): a bf16 TP-only-sharded compute copy is
    materialized ONCE per step outside the microbatch scan — one weight
    gather per step instead of ~3 x microbatches, and remat recomputes no
    gathers. Master params + optimizer state stay fully (fsdp x tp) sharded
    either way."""

    def loss_fn(params_c, micro):
        loss, metrics = model.loss(params_c, micro)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch: dict):
        if zero_stage == 2:
            from repro.parallel.sharding import cast_and_reshard_compute_params
            params_c = cast_and_reshard_compute_params(
                state.params, dtype=jnp.dtype(model.cfg.dtype))
        else:
            # ZeRO-3: keep full (fsdp x tp) sharding; cast to the compute
            # dtype so per-layer gathers move bf16, not fp32 masters.
            dt = jnp.dtype(model.cfg.dtype)
            params_c = jax.tree.map(
                lambda x: x.astype(dt)
                if jnp.issubdtype(x.dtype, jnp.floating) else x, state.params)

        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params_c, batch)
        else:
            micro = _split_micro(batch, microbatches)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                state.params)

            def acc_step(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(params_c, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), None

            (g_sum, l_sum), _ = jax.lax.scan(acc_step, (zero, 0.0), micro)
            grads = jax.tree.map(lambda g: g / microbatches, g_sum)
            loss = l_sum / microbatches
            metrics = {}

        new_params, new_opt, opt_metrics = apply_updates(
            state.params, grads, state.opt, opt_cfg)
        new_state = TrainState(params=new_params, opt=new_opt,
                               step=state.step + 1)
        out = {"loss": loss, **opt_metrics}
        return new_state, out

    return train_step
