"""Logical-axis sharding: models annotate with logical names ("dp", "fsdp",
"tp", "sp"); the launcher binds them to mesh axes. Outside a mesh context all
constraints are no-ops, so smoke tests run unmodified on one CPU device.

Bindings:
  single-pod (16, 16)   ("data", "model"):          dp/fsdp -> data, tp/sp -> model
  multi-pod (2, 16, 16) ("pod", "data", "model"):   dp/fsdp -> (pod, data), tp/sp -> model
"""
from __future__ import annotations

import contextlib
import re

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX: dict = {"mesh": None, "rules": {}}

RULES_SINGLE_POD = {"dp": ("data",), "fsdp": ("data",), "tp": ("model",),
                    "sp": ("model",)}
RULES_MULTI_POD = {"dp": ("pod", "data"), "fsdp": ("pod", "data"),
                   "tp": ("model",), "sp": ("model",)}
# Pure ZeRO-3 data parallelism: batch + parameter shards over EVERY chip, no
# tensor parallelism. At train_4k batch sizes this eliminates TP activation
# reduces and head-padding reshards entirely (EXPERIMENTS.md §Perf iter 5).
RULES_PURE_DP_SINGLE = {"dp": ("data", "model"), "fsdp": ("data", "model"),
                        "tp": None, "sp": None}
RULES_PURE_DP_MULTI = {"dp": ("pod", "data", "model"),
                       "fsdp": ("pod", "data", "model"), "tp": None, "sp": None}
# Prefill: batch over the data axis only (prefill_32k has B=32), parameters
# FSDP over the whole fleet, no TP — per-layer bf16 weight gathers cost far
# less than TP activation reduces at 32k tokens (§Perf iter 8).
RULES_PREFILL_SINGLE = {"dp": ("data",), "fsdp": ("data", "model"),
                        "tp": None, "sp": None}
RULES_PREFILL_MULTI = {"dp": ("pod", "data"),
                       "fsdp": ("pod", "data", "model"), "tp": None, "sp": None}


def rules_for_mesh(mesh: Mesh) -> dict:
    return RULES_MULTI_POD if "pod" in mesh.axis_names else RULES_SINGLE_POD


@contextlib.contextmanager
def use_mesh(mesh: Mesh | None, rules: dict | None = None):
    """Bind the ambient mesh + logical-axis rules (trace-time context)."""
    old = dict(_CTX)
    _CTX.update(mesh=mesh, rules=rules or (rules_for_mesh(mesh) if mesh else {}))
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _CTX.update(old)


def current_mesh() -> Mesh | None:
    return _CTX["mesh"]


def logical_to_spec(axes) -> P:
    rules = _CTX["rules"]
    parts = []
    for a in axes:
        if a is None:
            parts.append(None)
        else:
            r = rules.get(a)
            parts.append(r if r else None)
    return P(*parts)


def constrain(x, *axes):
    """with_sharding_constraint by logical axis names; no-op without a mesh."""
    mesh = _CTX["mesh"]
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, logical_to_spec(axes)))


# ---------------------------------------------------------------------------
# Parameter partition rules: path-name -> logical axes per dimension.
# Stacked (scanned) parameter subtrees contain a path component matching
# "stack"; their specs get a leading None for the layer axis (possibly two for
# doubly-stacked hybrid groups, resolved by rank difference).
# ---------------------------------------------------------------------------

_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embed$", ("tp", "fsdp")),            # (V, D); (K, V, D) handled by rank
    (r"lm_head$", ("fsdp", "tp")),
    (r"heads$", (None, "fsdp", "tp")),      # musicgen codebook heads (K, D, V)
    (r"patch_proj$", ("fsdp", "tp")),
    (r"router$", ("fsdp", None)),
    (r"w_in$", ("tp", "fsdp", None)),       # experts (E, D, 2F)
    (r"w_out$", ("tp", None, "fsdp")),      # experts (E, F, D)
    (r"(wqkv|wg|wu|wif|w_ogate|in_proj|w_gates)$", ("fsdp", "tp")),
    (r"(wo|wd|out_proj)$", ("tp", "fsdp")),
    (r"conv_w$", (None, "tp")),
    (r"(conv_b|bqkv|A_log|Dskip|dt_bias)$", ("tp",)),
    (r"ln_inner$", (None, None)),
    (r".*", (None,)),                        # norms, scalars, leftovers
]


def param_logical_axes(path: tuple[str, ...], ndim: int) -> tuple:
    name = path[-1]
    stacked_levels = sum(1 for p in path if "stack" in p)
    for pat, axes in _PARAM_RULES:
        if re.search(pat, name):
            axes = tuple(axes)
            # rank-adjust: pad leading Nones (stacking or extra leading dims)
            if len(axes) < ndim:
                axes = (None,) * (ndim - len(axes)) + axes
            elif len(axes) > ndim:
                axes = axes[len(axes) - ndim:]
            return axes
    return (None,) * ndim


def even_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop spec axes that do not evenly divide the dimension (argument
    shardings must divide; uneven cases fall back to replication on that dim
    and are recorded by the dry-run via the resulting spec)."""
    parts = []
    for i, ax in enumerate(spec):
        if ax is None:
            parts.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        parts.append(ax if shape[i] % size == 0 else None)
    return P(*parts)


def param_pspec_tree(params_shape_tree, mesh: Mesh | None = None):
    """PartitionSpec pytree for an (abstract) param tree via the rules.
    With `mesh` (or an ambient mesh), non-dividing axes are dropped."""
    mesh = mesh or _CTX["mesh"]

    def spec(path, leaf):
        keys = tuple(getattr(p, "key", getattr(p, "idx", str(p))) for p in path)
        keys = tuple(str(k) for k in keys)
        s = logical_to_spec(param_logical_axes(keys, len(leaf.shape)))
        return even_spec(s, leaf.shape, mesh) if mesh is not None else s
    return jax.tree_util.tree_map_with_path(spec, params_shape_tree)


def compute_param_specs(params_tree, mesh: Mesh | None = None):
    """TP-only specs for the bf16 COMPUTE copy of the weights (ZeRO-2): the
    fsdp axis is dropped so XLA gathers each weight ONCE per step (outside
    the microbatch scan) instead of per-layer-per-microbatch."""
    mesh = mesh or _CTX["mesh"]

    def spec(path, leaf):
        keys = tuple(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        axes = param_logical_axes(keys, len(leaf.shape))
        axes = tuple(None if a == "fsdp" else a for a in axes)
        s = logical_to_spec(axes)
        return even_spec(s, leaf.shape, mesh) if mesh is not None else s
    return jax.tree_util.tree_map_with_path(spec, params_tree)


def cast_and_reshard_compute_params(params, dtype=None):
    """bf16 cast + TP-only resharding constraint (no-op without a mesh)."""
    import jax.numpy as jnp
    dtype = dtype or jnp.bfloat16
    mesh = _CTX["mesh"]

    def cast(x):
        return x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x

    casted = jax.tree.map(cast, params)
    if mesh is None:
        return casted
    specs = compute_param_specs(casted, mesh)
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, s)), casted, specs)


def named_sharding_tree(mesh: Mesh, params_shape_tree):
    specs = None
    with use_mesh(mesh):
        specs = param_pspec_tree(params_shape_tree)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))
