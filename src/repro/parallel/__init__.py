"""Sharding and mesh utilities."""
