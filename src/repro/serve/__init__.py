"""Serving engine (prefill + decode tasks the scheduler dispatches)."""
from repro.serve.engine import ServeEngine
