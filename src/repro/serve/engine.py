"""Batched serving engine: prefill + decode with a persistent cache.

The engine is the unit the paper's scheduler dispatches: a `prefill` call or a
`decode_run` (n greedy steps) is one "task"; pools (repro.sched.cluster) own
one engine each and serve FCFS — mirroring the paper's real-platform setup
(OpenCL contexts with one queue per device, Sec. 7.1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.model import Model


class ServeEngine:
    def __init__(self, model: Model, params, max_len: int = 512):
        self.model = model
        self.cfg = model.cfg
        self.max_len = max_len
        # bf16 serving copy of the weights
        self.params = jax.tree.map(
            lambda p: p.astype(jnp.bfloat16)
            if jnp.issubdtype(p.dtype, jnp.floating) else p, params)
        self._prefill = jax.jit(
            functools.partial(model.prefill, cache_len=max_len))
        self._decode = jax.jit(model.decode_step)

    def prefill(self, batch: dict):
        logits, cache = self._prefill(self.params, batch)
        return logits, cache

    def _greedy_next(self, logits):
        """Greedy token from last-position logits: (emitted, feed) where
        `emitted` is (B,) — or (B, K) for audio codebooks — and `feed` has the
        trailing length-1 axis `decode_step` expects."""
        nxt = jnp.argmax(logits[:, -1], axis=-1)
        if self.cfg.family == "audio":
            return nxt, nxt[:, :, None].astype(jnp.int32)
        return nxt, nxt[:, None].astype(jnp.int32)

    def decode_run(self, first_token, cache, start_pos: int, steps: int):
        """Greedy-decode `steps` tokens. Returns (tokens, cache)."""
        tok = first_token
        out = []
        pos = start_pos
        for _ in range(steps):
            logits, cache = self._decode(self.params, tok, cache,
                                         jnp.asarray(pos, jnp.int32))
            nxt, tok = self._greedy_next(logits)
            out.append(nxt)
            pos += 1
        return jnp.stack(out, axis=1), cache

    def generate(self, batch: dict, steps: int):
        """prefill + greedy decode; returns generated token ids."""
        logits, cache = self.prefill(batch)
        first, feed = self._greedy_next(logits)
        if self.cfg.family == "audio":
            start = batch["tokens"].shape[-1]
        else:
            start = batch["tokens"].shape[1]
            if self.cfg.family == "vlm" and "patch_embeds" in batch:
                start += batch["patch_embeds"].shape[1]
        toks, cache = self.decode_run(feed, cache, start, steps - 1)
        return jnp.concatenate([first[:, None], toks], axis=1)


def request_service_fns(engine: ServeEngine, batch: dict, toks,
                        slowdown: int = 3):
    """Two request classes on two heterogeneous pools, as real work.

    Class 0 is a PREFILL request (one batched prefill — the interactive,
    latency-sensitive class) and class 1 a DECODE request (short prefill +
    a greedy decode run — the batch class). Pool 0 favors prefill, pool 1
    decode; the off-diagonal runs `slowdown` repetitions, giving the 2 x 2
    heterogeneous affinity the paper's CAB/GrIn placement exploits. Returns
    `service_fns` for `repro.sched.virtual.VirtualTimeCluster` — the shared
    service-function set behind `repro.launch.serve --heterogeneous` /
    `--traffic` and `examples/serve_heterogeneous.py`.
    """
    cfg = engine.cfg

    def prefill_task(size):
        logits, _ = engine.prefill(batch)
        jax.block_until_ready(logits)

    def decode_task(size):
        _, cache = engine.prefill(
            {k: (v[:, :4] if k == "tokens" and cfg.family != "audio"
                 else v) for k, v in batch.items()})
        o, _ = engine.decode_run(
            toks[:, :1] if cfg.family != "audio" else toks[:, :, :1],
            cache, 4, 4)
        jax.block_until_ready(o)

    def slow(fn, n):
        return lambda size: [fn(size) for _ in range(n)]

    return [{0: prefill_task, 1: slow(decode_task, slowdown)},
            {0: slow(prefill_task, slowdown), 1: decode_task}]


def with_retries(service_fn, *, max_attempts: int = 3,
                 retryable: tuple = (RuntimeError, OSError),
                 on_wasted=None):
    """Wrap one service fn with transient-failure re-execution.

    The serving analogue of `repro.faults` transient task failures: a
    retryable exception loses the whole attempt (full re-execution — there
    is no mid-request checkpoint in serving), the task re-runs up to
    `max_attempts` times, and every lost attempt is reported through
    `on_wasted(attempt_index)` so a driver can account wasted work against
    goodput. Non-retryable exceptions and exhaustion propagate.
    """
    if max_attempts < 1:
        raise ValueError(f"max_attempts must be >= 1, got {max_attempts}")

    def wrapped(size):
        for attempt in range(max_attempts):
            try:
                return service_fn(size)
            except retryable:
                if on_wasted is not None:
                    on_wasted(attempt)
                if attempt + 1 >= max_attempts:
                    raise
    return wrapped
