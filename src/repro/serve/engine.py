"""Batched serving engine: prefill + decode with a persistent cache.

The engine is the unit the paper's scheduler dispatches: a `prefill` call or a
`decode_run` (n greedy steps) is one "task"; pools (repro.sched.cluster) own
one engine each and serve FCFS — mirroring the paper's real-platform setup
(OpenCL contexts with one queue per device, Sec. 7.1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.model import Model


class ServeEngine:
    def __init__(self, model: Model, params, max_len: int = 512):
        self.model = model
        self.cfg = model.cfg
        self.max_len = max_len
        # bf16 serving copy of the weights
        self.params = jax.tree.map(
            lambda p: p.astype(jnp.bfloat16)
            if jnp.issubdtype(p.dtype, jnp.floating) else p, params)
        self._prefill = jax.jit(
            functools.partial(model.prefill, cache_len=max_len))
        self._decode = jax.jit(model.decode_step)

    def prefill(self, batch: dict):
        logits, cache = self._prefill(self.params, batch)
        return logits, cache

    def _greedy_next(self, logits):
        """Greedy token from last-position logits: (emitted, feed) where
        `emitted` is (B,) — or (B, K) for audio codebooks — and `feed` has the
        trailing length-1 axis `decode_step` expects."""
        nxt = jnp.argmax(logits[:, -1], axis=-1)
        if self.cfg.family == "audio":
            return nxt, nxt[:, :, None].astype(jnp.int32)
        return nxt, nxt[:, None].astype(jnp.int32)

    def decode_run(self, first_token, cache, start_pos: int, steps: int):
        """Greedy-decode `steps` tokens. Returns (tokens, cache)."""
        tok = first_token
        out = []
        pos = start_pos
        for _ in range(steps):
            logits, cache = self._decode(self.params, tok, cache,
                                         jnp.asarray(pos, jnp.int32))
            nxt, tok = self._greedy_next(logits)
            out.append(nxt)
            pos += 1
        return jnp.stack(out, axis=1), cache

    def generate(self, batch: dict, steps: int):
        """prefill + greedy decode; returns generated token ids."""
        logits, cache = self.prefill(batch)
        first, feed = self._greedy_next(logits)
        if self.cfg.family == "audio":
            start = batch["tokens"].shape[-1]
        else:
            start = batch["tokens"].shape[1]
            if self.cfg.family == "vlm" and "patch_embeds" in batch:
                start += batch["patch_embeds"].shape[1]
        toks, cache = self.decode_run(feed, cache, start, steps - 1)
        return jnp.concatenate([first[:, None], toks], axis=1)
