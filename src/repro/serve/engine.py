"""Batched serving engine: prefill + decode with a persistent cache.

The engine is the unit the paper's scheduler dispatches: a `prefill` call or a
`decode_run` (n greedy steps) is one "task"; pools (repro.sched.cluster) own
one engine each and serve FCFS — mirroring the paper's real-platform setup
(OpenCL contexts with one queue per device, Sec. 7.1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.model import Model


class ServeEngine:
    def __init__(self, model: Model, params, max_len: int = 512):
        self.model = model
        self.cfg = model.cfg
        self.max_len = max_len
        # bf16 serving copy of the weights
        self.params = jax.tree.map(
            lambda p: p.astype(jnp.bfloat16)
            if jnp.issubdtype(p.dtype, jnp.floating) else p, params)
        self._prefill = jax.jit(
            functools.partial(model.prefill, cache_len=max_len))
        self._decode = jax.jit(model.decode_step)

    def prefill(self, batch: dict):
        logits, cache = self._prefill(self.params, batch)
        return logits, cache

    def decode_run(self, first_token, cache, start_pos: int, steps: int):
        """Greedy-decode `steps` tokens. Returns (tokens, cache)."""
        tok = first_token
        out = []
        pos = start_pos
        for _ in range(steps):
            logits, cache = self._decode(self.params, tok, cache,
                                         jnp.asarray(pos, jnp.int32))
            if self.cfg.family == "audio":
                nxt = jnp.argmax(logits[:, -1], axis=-1)      # (B, K)
                tok = nxt[:, :, None].astype(jnp.int32)
            else:
                nxt = jnp.argmax(logits[:, -1], axis=-1)      # (B,)
                tok = nxt[:, None].astype(jnp.int32)
            out.append(nxt)
            pos += 1
        return jnp.stack(out, axis=1), cache

    def generate(self, batch: dict, steps: int):
        """prefill + greedy decode; returns generated token ids."""
        logits, cache = self.prefill(batch)
        if self.cfg.family == "audio":
            first = jnp.argmax(logits[:, -1], -1)[:, :, None].astype(jnp.int32)
            start = batch["tokens"].shape[-1]
        else:
            first = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
            start = batch["tokens"].shape[1]
            if self.cfg.family == "vlm" and "patch_embeds" in batch:
                start += batch["patch_embeds"].shape[1]
        toks, cache = self.decode_run(first, cache, start, steps - 1)
        first_axis = first[:, None] if self.cfg.family != "audio" else first[:, None, :, 0]
        return jnp.concatenate([
            first[:, None, ...].reshape(toks.shape[0], 1, *toks.shape[2:]),
            toks], axis=1)
