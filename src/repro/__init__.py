"""Reproduction of Chen & Marculescu, arXiv:1712.03209, grown into a
JAX serving/training stack (see ROADMAP.md)."""
