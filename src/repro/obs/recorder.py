"""Bounded ring-buffer flight recorder for structured decision events.

One `TraceRecorder` can be shared by every layer of a run (scheduler core,
admission controller, autoscale governor, fault loops): each layer records
`(t, layer, kind, data)` tuples and the recorder keeps the most recent
`capacity` of them, counting what it had to drop. Export is Chrome
trace-event JSON — loadable in chrome://tracing / Perfetto and summarized
by `tools/trace_view.py`.

Determinism: export is byte-deterministic for a deterministic event stream
(sorted JSON keys, no wall-clock stamps — event times are SIMULATION times
supplied by the caller, or a monotone sequence number when the caller has
no clock). The trace-determinism tests pin this.
"""
from __future__ import annotations

import dataclasses
import json
from collections import Counter, deque

# Stable tid assignment per layer in the Chrome export (unknown layers get
# the next free id in first-seen order — still deterministic per stream).
_LAYER_TIDS = {"sched": 1, "admission": 2, "governor": 3, "faults": 4,
               "profile": 5, "host": 6}


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One recorded decision: time, producing layer, event kind, payload."""

    t: float
    layer: str
    kind: str
    data: dict


class TraceRecorder:
    """Bounded ring buffer of `TraceEvent`s with Chrome-trace export.

    capacity bounds memory: the buffer keeps the most recent `capacity`
    events and `dropped` counts the overwritten ones. `record` is the
    single hot-path entry — callers guard it behind an
    `if recorder is not None` so an unattached run pays nothing.
    """

    def __init__(self, capacity: int = 65536):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1; got {capacity}")
        self.capacity = int(capacity)
        self._events: deque[TraceEvent] = deque(maxlen=self.capacity)
        self._seq = 0
        self.dropped = 0

    # ---------------- recording ----------------
    def record(self, layer: str, kind: str, t: float | None = None,
               **data) -> None:
        """Append one event. `t` is the caller's (simulation) clock; when
        the caller has no clock the monotone record sequence number stands
        in, so event order is still total."""
        if len(self._events) == self._events.maxlen:
            self.dropped += 1
        self._events.append(TraceEvent(
            t=float(self._seq if t is None else t), layer=layer, kind=kind,
            data=data))
        self._seq += 1

    def clear(self) -> None:
        self._events.clear()
        self._seq = 0
        self.dropped = 0

    # ---------------- inspection ----------------
    @property
    def events(self) -> list[TraceEvent]:
        return list(self._events)

    def __len__(self) -> int:
        return len(self._events)

    def counts(self) -> dict[tuple[str, str], int]:
        """{(layer, kind): count} over the retained events."""
        return dict(Counter((e.layer, e.kind) for e in self._events))

    def layer_counts(self) -> dict[str, int]:
        """{layer: count} over the retained events."""
        return dict(Counter(e.layer for e in self._events))

    # ---------------- export ----------------
    def to_chrome_trace(self, spans=None) -> list[dict]:
        """Chrome trace-event list: every recorded event as an instant
        (`ph: "i"`) event, plus optional profiling `spans`
        (`repro.obs.profile.ProfileSpan`) as complete (`ph: "X"`) events.
        Timestamps are microseconds per the format; simulation seconds map
        1 s -> 1e6 us."""
        tids = dict(_LAYER_TIDS)
        out = []
        for e in self._events:
            tid = tids.setdefault(e.layer, max(tids.values()) + 1)
            out.append({"name": e.kind, "cat": e.layer, "ph": "i",
                        "ts": e.t * 1e6, "pid": 1, "tid": tid, "s": "t",
                        "args": _jsonable(e.data)})
        for s in spans or ():
            out.append({"name": s.name, "cat": "profile", "ph": "X",
                        "ts": s.t0 * 1e6, "dur": s.dur * 1e6, "pid": 1,
                        "tid": tids["profile"], "args": {}})
        return out

    def export(self, path: str, spans=None) -> int:
        """Write Chrome trace JSON; returns the number of events written.
        Byte-deterministic for a deterministic event stream."""
        events = self.to_chrome_trace(spans=spans)
        doc = {"traceEvents": events, "displayTimeUnit": "ms",
               "metadata": {"dropped": self.dropped,
                            "capacity": self.capacity}}
        with open(path, "w") as f:
            json.dump(doc, f, sort_keys=True, separators=(",", ":"))
        return len(events)


def _jsonable(data: dict) -> dict:
    """Coerce event payloads (numpy scalars/arrays sneak in) to JSON types."""
    out = {}
    for key, v in data.items():
        if hasattr(v, "tolist"):
            v = v.tolist()
        elif hasattr(v, "item"):
            v = v.item()
        out[key] = v
    return out


__all__ = ["TraceRecorder", "TraceEvent"]
