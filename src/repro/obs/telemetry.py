"""Time-resolved telemetry: fixed-bin series shared by host and device.

The engine scan cores (`repro.sim.engine_jax`, `repro.traffic.engine`) can
carry four fixed-bin time series through the run — per-pool occupancy,
per-pool true-work backlog, total power draw, and in-flight hedge count —
and the host oracle loops accumulate the identical series through
`TelemetryAccumulator` (the twin the conformance cell compares against).

Binning convention (both sides MUST match):

  * the horizon [0, H] splits into `n_bins` equal bins (open mode:
    H = t_end, the last arrival's time; closed mode: the caller supplies
    H);
  * each inter-event interval [t, t + dt) charges its dt-weighted
    quantities to the bin containing the interval's START, with the charge
    clipped at the horizon (time past H charges nothing — the host loop
    stops at the last arrival while the device core keeps draining, so
    unclipped tails would diverge);
  * `telemetry_series` converts the raw integrals to per-bin time
    averages by dividing by the bin width.

Telemetry off (n_bins = 0) is a trace-time static in the engines: the
carried state tuple is empty, the stanza is dropped from the jaxpr, and
the compiled program — and every result — is unchanged (pinned by the
bit-identity tests).
"""
from __future__ import annotations

import numpy as np


class TelemetryAccumulator:
    """Host twin of the device telemetry carries.

    add(t, dt, pool_counts, pool_backlog, power, hedges) charges one
    inter-event interval starting at `t`; series() returns the same
    raw-integral arrays the device core produces.
    """

    def __init__(self, n_bins: int, horizon: float, n_pools: int):
        if n_bins < 1:
            raise ValueError(f"n_bins must be >= 1; got {n_bins}")
        if not horizon > 0:
            raise ValueError(f"horizon must be > 0; got {horizon}")
        self.n_bins = int(n_bins)
        self.horizon = float(horizon)
        self.bin_width = self.horizon / self.n_bins
        self.occupancy = np.zeros((self.n_bins, n_pools))
        self.backlog = np.zeros((self.n_bins, n_pools))
        self.power = np.zeros(self.n_bins)
        self.hedges = np.zeros(self.n_bins)

    def add(self, t: float, dt: float, pool_counts, pool_backlog,
            power: float, hedges: float = 0.0) -> None:
        """Charge the interval [t, t + dt) to the bin containing t, clipped
        at the horizon."""
        if dt <= 0.0 or t >= self.horizon:
            return
        w = min(t + dt, self.horizon) - t
        b = min(int(t / self.bin_width), self.n_bins - 1)
        self.occupancy[b] += w * np.asarray(pool_counts, dtype=np.float64)
        self.backlog[b] += w * np.asarray(pool_backlog, dtype=np.float64)
        self.power[b] += w * power
        self.hedges[b] += w * hedges

    def series(self) -> dict:
        """Raw dt-weighted integrals per bin (device-core layout)."""
        return {"occupancy": self.occupancy.copy(),
                "backlog": self.backlog.copy(), "power": self.power.copy(),
                "hedges": self.hedges.copy(),
                "bin_width": self.bin_width, "horizon": self.horizon}


def telemetry_series(raw: dict) -> dict:
    """Convert raw per-bin integrals to per-bin TIME AVERAGES (divide by
    the bin width). Works on host (`TelemetryAccumulator.series()`) and
    device (`simulate_*_batch` "telemetry" entries, per batch row) output;
    batch leading axes pass through."""
    bw = np.asarray(raw["bin_width"], dtype=np.float64)
    out = {"bin_width": bw, "horizon": raw["horizon"]}
    for key in ("occupancy", "backlog", "power", "hedges"):
        v = np.asarray(raw[key], dtype=np.float64)
        if v.ndim and bw.ndim:        # batched: bin axis follows batch axes
            shape = bw.shape + (1,) * (v.ndim - bw.ndim)
            out[key] = v / np.maximum(bw.reshape(shape), 1e-30)
        else:
            out[key] = v / max(float(bw), 1e-30)
    return out


__all__ = ["TelemetryAccumulator", "telemetry_series"]
