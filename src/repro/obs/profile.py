"""Wall-clock profiling spans for the hot solver paths.

JAX dispatch is asynchronous: a naive `time.perf_counter` pair around a
device call times the *enqueue*, not the work. The profiler's `span`
context therefore calls `jax.block_until_ready` on whatever the caller
hands to `span.ready(...)` before closing the span — but ONLY when
profiling is enabled, so the production path keeps its async pipelining.

Off by default. `enable_profiling()` flips a module-level flag checked
once per instrumented call; disabled cost is one attribute read. The
instrumented entry points (PR 10): `solve_targets_jax`,
`solve_targets_grid_jax`, `grin_solve_batch_jax`,
`SchedulerCore.route_many`, and the Pallas gain-kernel host entry
(`block_move_scores`, skipped under a jit trace where wall time is
meaningless).

    >>> from repro.obs import enable_profiling, get_profiler
    >>> enable_profiling()
    >>> ...  # run solves
    >>> get_profiler().summary()            # name -> count/total/mean/max
    >>> get_profiler().top_spans(5)         # slowest individual spans
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from collections import deque

_MAX_SPANS = 16384


@dataclasses.dataclass(frozen=True)
class ProfileSpan:
    """One completed span: label, start (perf_counter seconds), duration."""

    name: str
    t0: float
    dur: float


class _ActiveSpan:
    """Context manager for one live span; `ready(x)` blocks on device work
    (and returns x) so the span covers execution, not just dispatch."""

    __slots__ = ("_profiler", "name", "_t0")

    def __init__(self, profiler: "Profiler", name: str):
        self._profiler = profiler
        self.name = name

    def ready(self, x):
        import jax
        return jax.block_until_ready(x)

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._profiler._push(ProfileSpan(
            name=self.name, t0=self._t0,
            dur=time.perf_counter() - self._t0))
        return False


class _NullSpan:
    """Disabled-path span: no timing, `ready` is the identity."""

    __slots__ = ()

    def ready(self, x):
        return x

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class Profiler:
    """Span collector: bounded deque of completed `ProfileSpan`s."""

    def __init__(self, enabled: bool = False, max_spans: int = _MAX_SPANS):
        self.enabled = bool(enabled)
        self._spans: deque[ProfileSpan] = deque(maxlen=int(max_spans))

    def _push(self, span: ProfileSpan) -> None:
        self._spans.append(span)

    def span(self, name: str):
        """`with profiler.span("solve"): ...` — a no-op when disabled."""
        if not self.enabled:
            return _NULL_SPAN
        return _ActiveSpan(self, name)

    @property
    def spans(self) -> list[ProfileSpan]:
        return list(self._spans)

    def clear(self) -> None:
        self._spans.clear()

    def summary(self) -> dict[str, dict]:
        """{name: {count, total_s, mean_s, max_s}} over retained spans."""
        agg: dict[str, dict] = {}
        for s in self._spans:
            row = agg.setdefault(s.name, {"count": 0, "total_s": 0.0,
                                          "max_s": 0.0})
            row["count"] += 1
            row["total_s"] += s.dur
            row["max_s"] = max(row["max_s"], s.dur)
        for row in agg.values():
            row["mean_s"] = row["total_s"] / row["count"]
        return agg

    def top_spans(self, k: int = 10) -> list[ProfileSpan]:
        """The k slowest individual spans, slowest first."""
        return sorted(self._spans, key=lambda s: -s.dur)[:k]


_PROFILER = Profiler(enabled=False)


def get_profiler() -> Profiler:
    return _PROFILER


def enable_profiling(on: bool = True) -> Profiler:
    """Turn the module-level profiler on (or off); returns it."""
    _PROFILER.enabled = bool(on)
    return _PROFILER


def span(name: str):
    """Module-level span against the default profiler (the instrumented
    library call sites use this)."""
    if not _PROFILER.enabled:
        return _NULL_SPAN
    return _ActiveSpan(_PROFILER, name)


@contextlib.contextmanager
def profile_block(name: str):
    """Enable profiling for a `with` block, restoring the prior state."""
    prev = _PROFILER.enabled
    _PROFILER.enabled = True
    try:
        yield _PROFILER
    finally:
        _PROFILER.enabled = prev


__all__ = ["Profiler", "ProfileSpan", "get_profiler", "enable_profiling",
           "span", "profile_block"]
