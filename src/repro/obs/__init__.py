"""Flight-recorder observability layer (`repro.obs`).

Three instruments, all opt-in and zero-cost when disabled:

  * `TraceRecorder` — a bounded ring buffer of structured decision events
    (routing, target re-solves with cache hit/miss/eviction, admission
    shed/defer, governor decisions, fault breakpoints), exportable to
    Chrome trace-event JSON (chrome://tracing, Perfetto, `tools/
    trace_view.py`). Attach one to a `SchedulerCore` / `AdmissionController`
    / `AutoscaleGovernor`; with none attached the hot paths skip a single
    `is not None` check.
  * Profiling spans (`repro.obs.profile`) — `block_until_ready`-aware
    wall-clock spans around the hot solver entry points
    (`solve_targets_grid_jax`, `grin_solve_batch_jax`, `route_many`, the
    Pallas gain kernel). Off by default (`enable_profiling()`).
  * Time-resolved telemetry (`repro.obs.telemetry`) — fixed-bin device
    time series (per-pool occupancy, backlog, power, in-flight hedges)
    carried through the `lax.scan` engine cores, with a host twin in the
    oracle loops. Telemetry off is a trace-time static: the compiled
    program (and every result) is unchanged.

`run_meta()` (`repro.obs.meta`) stamps benchmark payloads with the jax
backend, kernel mode and dtype so perf numbers stay attributable.
"""
from repro.obs.meta import run_meta
from repro.obs.profile import (Profiler, enable_profiling, get_profiler,
                               profile_block, span)
from repro.obs.recorder import TraceEvent, TraceRecorder
from repro.obs.telemetry import TelemetryAccumulator, telemetry_series

__all__ = ["TraceRecorder", "TraceEvent", "Profiler", "span",
           "enable_profiling", "get_profiler", "profile_block", "run_meta",
           "TelemetryAccumulator", "telemetry_series"]
