"""Run metadata: make every perf number attributable.

`run_meta()` captures the execution substrate a measurement ran on — jax
backend, Pallas kernel mode (compiled TPU kernel vs interpret-mode vs the
jnp reference), compute dtype, versions. `benchmarks.common.save_json`
attaches it to every BENCH payload and the engines stamp it onto
SimMetrics rows, so a "1.4x faster" claim always says 1.4x faster *where*.
"""
from __future__ import annotations

import platform


def kernel_mode() -> str:
    """Which gain-scoring path `repro.kernels.grin_moves` will take:
    "pallas-compiled" (real TPU), "pallas-interpret"
    (REPRO_PALLAS_INTERPRET=1), or "jnp-reference"."""
    from repro.kernels.grin_moves import _interpret, _use_pallas
    if _use_pallas():
        return "pallas-compiled"
    if _interpret():
        return "pallas-interpret"
    return "jnp-reference"


def run_meta() -> dict:
    """Machine-readable substrate block for benchmark payloads / metrics."""
    import jax
    return {
        "jax_backend": jax.default_backend(),
        "jax_version": jax.__version__,
        "kernel_mode": kernel_mode(),
        "dtype": "float32",              # the engines' device state dtype
        "python": platform.python_version(),
        "platform": platform.system().lower(),
    }


__all__ = ["run_meta", "kernel_mode"]
